//! The serving coordinator: a dispatcher thread (dynamic batcher + round-
//! robin tile scheduler) feeding a pool of worker threads, each owning a
//! simulated analog core over *shared* read-only state: one
//! `ModelRegistry` (every worker clones `Arc<dyn Model>` — weights exist
//! once), one `PlanStore` (every layer's `RnsPlan` exists once, whichever
//! worker builds it first; `Model::warm` from W workers deduplicates to
//! one build per layer), and — for native RNS backends — one
//! `ExecutionFabric` (every worker's engine fans GEMM shards onto one
//! process-wide `WorkerPool` under a per-worker helper budget, so total
//! fan-out threads are bounded by cores − 1 regardless of W).
//!
//! Engines wrapping PJRT state are not `Send`, so every worker constructs
//! its own backend *inside* its thread — mirroring how a real deployment
//! pins one accelerator context per worker.  The RRNS detect→recompute
//! loop (paper §IV) runs inside the core; its fault counters are merged
//! into the serving metrics — globally and per model — and the plan
//! store's and fabric's counters land in the shutdown report.
//!
//! **Control plane.**  Alongside each worker's batch channel runs a
//! control channel (std mpsc has no select, so workers poll it between
//! batches and while idle-waiting).  `Coordinator::unload_model` uses it
//! to *proactively* release worker-held state — each worker drops its
//! cached `Arc<dyn Model>` and stale plan adoptions and acks, so an
//! unloaded model's memory is freed even if no worker ever sees the name
//! again — and `shutdown` drains workers through the same channel (a
//! `Shutdown` control message; queued batches still complete first).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analog::{FixedPointCore, Fp32Backend, GemmBackend, NoiseModel, RnsCore, RnsCoreConfig};
use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher, FormedBatch};
use crate::coordinator::metrics::{GatewayReport, ServingMetrics};
use crate::coordinator::request::{InferenceRequest, InferenceResponse, RequestId};
use crate::coordinator::router::RoutingKind;
use crate::nn::models::{Batch, Model, ModelRegistry};
use crate::runtime::fabric::{ExecutionFabric, FabricHandle};
use crate::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use crate::runtime::{ModularGemmEngine, NativeEngine};
use crate::store::{PlanStore, DEFAULT_UNTAGGED_CAPACITY};
use crate::tensor::{MatF, Nhwc};

/// Which simulated hardware the workers run.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// FP32 reference (no analog hardware).
    Fp32,
    /// Regular fixed-point analog core (b_adc = bits).
    FixedPoint { bits: u32 },
    /// RNS analog core; `redundant > 0` enables the RRNS retry loop.
    Rns { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
    /// RNS core executing through the AOT pallas kernel via PJRT.
    RnsPjrt { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendKind,
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub artifacts_dir: String,
    /// Analog array height.
    pub h: usize,
    pub seed: u64,
    /// Worker routing policy (round-robin or least-outstanding).
    pub routing: RoutingKind,
    /// LRU bound for *untagged* plans in the shared plan store (served
    /// models' plans are tagged and pinned until unload).
    pub plan_store_capacity: usize,
    /// Total thread budget for the shared execution fabric (native RNS
    /// backends): 0 = auto (`RNS_NATIVE_THREADS`, else core count).
    pub fabric_threads: usize,
}

impl CoordinatorConfig {
    pub fn new(backend: BackendKind, artifacts_dir: &str) -> Self {
        CoordinatorConfig {
            backend,
            workers: 2,
            batcher: BatcherConfig::default(),
            artifacts_dir: artifacts_dir.to_string(),
            h: 128,
            seed: 0,
            routing: RoutingKind::default(),
            plan_store_capacity: DEFAULT_UNTAGGED_CAPACITY,
            fabric_threads: 0,
        }
    }
}

/// How often an idle worker re-checks its control channel while blocked
/// waiting for batches (std mpsc has no select; 20 ms bounds proactive-
/// unload latency without measurable idle cost).
const CONTROL_POLL: Duration = Duration::from_millis(20);

/// How long `unload_model` waits for each worker's release ack before
/// giving up (a worker mid-forward acks after its current batch).
const UNLOAD_ACK_TIMEOUT: Duration = Duration::from_secs(30);

/// Control-plane messages delivered alongside the batch stream.
enum ControlMsg {
    /// Drop the cached `Arc<dyn Model>` and per-model backend state for
    /// `model`; reply on `ack`.
    Unload { model: String, ack: Sender<UnloadAck> },
    /// Finish every already-queued batch, then exit.
    Shutdown,
}

/// One worker's reply to `ControlMsg::Unload`.
struct UnloadAck {
    /// Whether the worker actually held (and dropped) a cached instance.
    dropped: bool,
}

/// What the message pump hands the worker's event handler.
enum WorkerEvent {
    Batch(FormedBatch),
    Unload { model: String, ack: Sender<UnloadAck> },
}

/// Per-request response routing callback (registered by
/// `CoordinatorHandle::submit_routed`; the TCP gateway's session threads
/// use it to steer each reply back to the session that asked).
type DeliverFn = Box<dyn FnOnce(InferenceResponse) + Send>;

/// Request id → delivery callback for routed submissions.
type ResponseRoutes = Arc<Mutex<HashMap<RequestId, DeliverFn>>>;

/// How workers hand responses back: a routed request's callback wins,
/// everything else lands on the coordinator's default response channel
/// (the in-process `recv`/`collect` API).
#[derive(Clone)]
struct Responder {
    default_tx: Sender<InferenceResponse>,
    routes: ResponseRoutes,
}

impl Responder {
    fn deliver(&self, resp: InferenceResponse) {
        // take the callback out under the lock, call it after: a delivery
        // callback may itself take locks (gateway latency percentiles)
        let cb = self.routes.lock().unwrap().remove(&resp.id);
        match cb {
            Some(cb) => cb(resp),
            None => {
                self.default_tx.send(resp).ok();
            }
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    /// Shared with every `CoordinatorHandle`; `shutdown` takes the inner
    /// sender so *all* handles see the closed door at once (otherwise a
    /// live gateway handle would keep the dispatcher alive forever).
    submit_tx: Arc<Mutex<Option<Sender<InferenceRequest>>>>,
    resp_rx: Receiver<InferenceResponse>,
    next_id: Arc<AtomicU64>,
    routes: ResponseRoutes,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// Per-worker control channels (proactive unload + shutdown drain).
    /// Behind a mutex so `CoordinatorHandle` (shared across gateway
    /// session threads) stays `Sync` on every supported toolchain.
    control_txs: Arc<Mutex<Vec<Sender<ControlMsg>>>>,
    metrics: Arc<Mutex<ServingMetrics>>,
    /// Shared read-only plan store (one `RnsPlan` per layer across all
    /// workers); its counters land in the shutdown report.
    store: Arc<PlanStore>,
    /// Shared load-once model instances (one weight copy across workers).
    registry: Arc<ModelRegistry>,
    /// Shared execution fabric (native RNS backends only): one pool of
    /// fan-out threads for all workers, with per-worker budgets.
    fabric: Option<Arc<ExecutionFabric>>,
    started: Instant,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (submit_tx, submit_rx) = mpsc::channel::<InferenceRequest>();
        let (resp_tx, resp_rx) = mpsc::channel::<InferenceResponse>();
        let (done_tx, done_rx) = mpsc::channel::<usize>();
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        // built once at startup, handed to every worker: the store is the
        // cross-worker plan memory, the registry the cross-worker
        // weights, the fabric the cross-worker thread budget
        let store = Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity));
        let registry = Arc::new(ModelRegistry::new(&cfg.artifacts_dir));
        let fabric = match &cfg.backend {
            BackendKind::Rns { .. } => Some(Arc::new(if cfg.fabric_threads > 0 {
                ExecutionFabric::with_threads(cfg.fabric_threads, cfg.workers.max(1))
            } else {
                ExecutionFabric::for_workers(cfg.workers.max(1))
            })),
            // FP32 / fixed-point / PJRT backends never touch the native
            // parallel engine — no fan-out threads to share
            _ => None,
        };

        let routes: ResponseRoutes = Arc::new(Mutex::new(HashMap::new()));
        let responder = Responder { default_tx: resp_tx, routes: Arc::clone(&routes) };

        let mut worker_txs = Vec::new();
        let mut control_txs = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = mpsc::channel::<FormedBatch>();
            let (ctrl_tx, ctrl_rx) = mpsc::channel::<ControlMsg>();
            worker_txs.push(tx);
            control_txs.push(ctrl_tx);
            let shared = WorkerShared {
                cfg: cfg.clone(),
                store: Arc::clone(&store),
                registry: Arc::clone(&registry),
                responder: responder.clone(),
                done_tx: done_tx.clone(),
                metrics: Arc::clone(&metrics),
                fabric: fabric.as_ref().map(|f| f.handle()),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rns-worker-{wid}"))
                    .spawn(move || worker_loop(wid, shared, rx, ctrl_rx))
                    .expect("spawn worker"),
            );
        }

        let batcher_cfg = cfg.batcher;
        let routing = cfg.routing;
        let metrics_d = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("rns-dispatcher".into())
            .spawn(move || {
                dispatcher_loop(submit_rx, worker_txs, batcher_cfg, routing, done_rx, metrics_d)
            })
            .expect("spawn dispatcher");

        Coordinator {
            submit_tx: Arc::new(Mutex::new(Some(submit_tx))),
            resp_rx,
            next_id: Arc::new(AtomicU64::new(1)),
            routes,
            dispatcher: Some(dispatcher),
            workers,
            control_txs: Arc::new(Mutex::new(control_txs)),
            metrics,
            store,
            registry,
            fabric,
            started: Instant::now(),
        }
    }

    /// A clonable, thread-safe handle onto this coordinator: submit with
    /// per-request response routing, load/unload models, and render the
    /// live metrics report.  This is the surface the TCP gateway's
    /// acceptor and session threads hold (the `Coordinator` itself owns
    /// the response receiver and cannot be shared).
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle {
            submit_tx: Arc::clone(&self.submit_tx),
            next_id: Arc::clone(&self.next_id),
            routes: Arc::clone(&self.routes),
            metrics: Arc::clone(&self.metrics),
            store: Arc::clone(&self.store),
            registry: Arc::clone(&self.registry),
            fabric: self.fabric.as_ref().map(Arc::clone),
            control_txs: Arc::clone(&self.control_txs),
            started: self.started,
        }
    }

    /// The shared plan store (one `Arc<RnsPlan>` per layer across all
    /// workers).  Exposed for tests and ops tooling.
    pub fn plan_store(&self) -> Arc<PlanStore> {
        Arc::clone(&self.store)
    }

    /// The shared model registry (one weight copy across all workers).
    pub fn model_registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// The shared execution fabric, if this backend uses one (native RNS
    /// cores).  Exposed so tests can assert the process-wide thread
    /// bound and ops tooling can read utilization.
    pub fn fabric(&self) -> Option<Arc<ExecutionFabric>> {
        self.fabric.as_ref().map(Arc::clone)
    }

    /// Drop a model's shared weights, evict its plans from the store,
    /// and — through the control plane — make every worker release its
    /// cached `Arc<dyn Model>` and stale plan adoptions *now*, without
    /// waiting for the name to be requested again.
    ///
    /// Ordering: the store unloads first (the name starts draining, so a
    /// batch racing the unload cannot re-pin dead-allocation plans),
    /// then the registry, then the control fan-out.  Each worker acks
    /// after its current batch at the latest; once every worker has
    /// acked, nothing can reference the old generation anymore, so the
    /// store's draining state is ended here (keyed off the acks) instead
    /// of waiting for the next warm's `activate_model`.  If an ack times
    /// out the name stays draining — the conservative pre-control-plane
    /// behavior.  Returns how many plans were evicted.
    pub fn unload_model(&self, name: &str) -> usize {
        unload_model_via(&self.store, &self.registry, &self.control_txs, &self.metrics, name)
    }

    /// Submit a request; returns its id immediately.
    pub fn submit(&self, model: &str, input: Batch) -> RequestId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferenceRequest::new(id, model, input);
        self.submit_tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("coordinator running")
            .send(req)
            .expect("dispatcher alive");
        id
    }

    /// Blocking receive of the next completed response.
    pub fn recv(&self) -> Option<InferenceResponse> {
        self.resp_rx.recv().ok()
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Option<InferenceResponse> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Drain exactly `n` responses (in completion order).
    pub fn collect(&self, n: usize) -> Vec<InferenceResponse> {
        (0..n).filter_map(|_| self.recv()).collect()
    }

    /// Stop accepting requests, drain workers through the control plane,
    /// and return the final report (plan store, fabric, and per-model
    /// counters included).
    pub fn shutdown(mut self) -> String {
        // taking the shared Option drops the one real sender, so every
        // CoordinatorHandle clone is closed too and the dispatcher sees
        // the channel disconnect
        self.submit_tx.lock().unwrap().take();
        if let Some(d) = self.dispatcher.take() {
            d.join().ok();
        }
        // every batch is now queued at some worker: drain via the control
        // plane (workers finish their queues before exiting)
        for tx in self.control_txs.lock().unwrap().iter() {
            tx.send(ControlMsg::Shutdown).ok();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
        let wall = self.started.elapsed();
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        if let Some(f) = &self.fabric {
            m.set_fabric(f.stats());
        }
        m.report(wall)
    }
}

/// Clonable, `Send + Sync` view onto a running coordinator — the surface
/// gateway session threads (and any other concurrent submitter) hold.
/// Every clone shares the coordinator's submit door: after
/// `Coordinator::shutdown` takes the sender, `submit_routed` on any
/// handle returns an error instead of hanging.
#[derive(Clone)]
pub struct CoordinatorHandle {
    submit_tx: Arc<Mutex<Option<Sender<InferenceRequest>>>>,
    next_id: Arc<AtomicU64>,
    routes: ResponseRoutes,
    metrics: Arc<Mutex<ServingMetrics>>,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    fabric: Option<Arc<ExecutionFabric>>,
    control_txs: Arc<Mutex<Vec<Sender<ControlMsg>>>>,
    started: Instant,
}

impl CoordinatorHandle {
    /// Submit with per-request response routing: `deliver` is invoked
    /// (once, from the worker that served the batch) with this request's
    /// response instead of the response landing on `Coordinator::recv`.
    /// Registration happens before the send, so a response can never
    /// race past its route.
    pub fn submit_routed(
        &self,
        model: &str,
        input: Batch,
        deliver: impl FnOnce(InferenceResponse) + Send + 'static,
    ) -> Result<RequestId, String> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.routes.lock().unwrap().insert(id, Box::new(deliver));
        let sent = match self.submit_tx.lock().unwrap().as_ref() {
            Some(tx) => tx.send(InferenceRequest::new(id, model, input)).is_ok(),
            None => false,
        };
        if !sent {
            self.routes.lock().unwrap().remove(&id);
            return Err("coordinator is shut down".into());
        }
        Ok(id)
    }

    /// Load a model into the shared registry now (workers still warm
    /// their plans on first batch).  An explicit gateway `LoadModel`
    /// frame pays the filesystem load before traffic arrives.
    pub fn load_model(&self, name: &str) -> Result<(), String> {
        self.registry.get_or_load(name).map(|_| ())
    }

    /// Proactive model unload through the worker control plane; see
    /// `Coordinator::unload_model`.  Returns evicted plan count.
    pub fn unload_model(&self, name: &str) -> usize {
        unload_model_via(&self.store, &self.registry, &self.control_txs, &self.metrics, name)
    }

    /// Render the live metrics report (same shape as the shutdown
    /// report, including the plan-store and fabric blocks) without
    /// stopping anything — the `Stats` frame and `GET /metrics` body.
    pub fn live_report(&self) -> String {
        let wall = self.started.elapsed();
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        if let Some(f) = &self.fabric {
            m.set_fabric(f.stats());
        }
        m.report(wall)
    }

    /// Attach the gateway's session/frame counters so they render in
    /// every subsequent report (live and shutdown).
    pub fn set_gateway_report(&self, g: GatewayReport) {
        self.metrics.lock().unwrap().set_gateway(g);
    }
}

/// Shared implementation of the proactive unload (used by the owning
/// `Coordinator` and by every `CoordinatorHandle`): store unload first
/// (the name starts draining), then registry, then the control fan-out,
/// then end the draining state once every worker acked.
fn unload_model_via(
    store: &Arc<PlanStore>,
    registry: &Arc<ModelRegistry>,
    control_txs: &Arc<Mutex<Vec<Sender<ControlMsg>>>>,
    metrics: &Arc<Mutex<ServingMetrics>>,
    name: &str,
) -> usize {
    let evicted = store.unload_model(name);
    registry.unload(name);
    let (ack_tx, ack_rx) = mpsc::channel();
    let mut sent = 0usize;
    for tx in control_txs.lock().unwrap().iter() {
        if tx.send(ControlMsg::Unload { model: name.to_string(), ack: ack_tx.clone() }).is_ok() {
            sent += 1;
        }
    }
    drop(ack_tx);
    let mut acked = 0usize;
    let mut released = 0u64;
    while acked < sent {
        match ack_rx.recv_timeout(UNLOAD_ACK_TIMEOUT) {
            Ok(ack) => {
                acked += 1;
                if ack.dropped {
                    released += 1;
                }
            }
            Err(_) => break,
        }
    }
    if acked == sent {
        // every worker released: a later request for the name loads
        // a fresh instance and pins fresh plans as usual
        store.activate_model(name);
    } else {
        crate::log_warn!(
            "coordinator",
            "unload `{name}`: only {acked}/{sent} workers acked; name stays draining"
        );
    }
    metrics.lock().unwrap().record_unload(released);
    evicted
}

fn dispatcher_loop(
    submit_rx: Receiver<InferenceRequest>,
    worker_txs: Vec<Sender<FormedBatch>>,
    batcher_cfg: BatcherConfig,
    routing: RoutingKind,
    done_rx: Receiver<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
) {
    let mut batcher = DynamicBatcher::new(batcher_cfg);
    let mut policy = routing.build();
    let mut open = true;
    while open || batcher.pending() > 0 {
        if open {
            match submit_rx.recv_timeout(batcher_cfg.max_wait.max(Duration::from_micros(100))) {
                Ok(req) => batcher.push(req),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // completion feedback for load-aware policies
        while let Ok(wid) = done_rx.try_recv() {
            policy.on_complete(wid);
        }
        let force = !open;
        while let Some(batch) = batcher.pop_ready(Instant::now(), force) {
            metrics.lock().unwrap().record_batch(batch.input.len());
            let wid = policy.pick(worker_txs.len());
            policy.on_dispatch(wid);
            worker_txs[wid].send(batch).ok();
        }
    }
    // dropping worker_txs closes the batch channels; the coordinator's
    // shutdown (or teardown) ends the workers through the control plane
}

/// Construct the configured backend with a private plan store (the CLI /
/// examples path — a single core gains nothing from sharing).  Engines
/// wrapping PJRT state are not `Send`; call this from the thread that
/// will use the backend.
pub fn build_backend(cfg: &CoordinatorConfig, wid: usize) -> Result<Box<dyn GemmBackend>, String> {
    build_backend_with_store(cfg, wid, Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity)))
}

/// `build_backend_with_runtime` without a fabric: the native engine owns
/// a private pool (standalone cores, sweeps).
pub fn build_backend_with_store(
    cfg: &CoordinatorConfig,
    wid: usize,
    store: Arc<PlanStore>,
) -> Result<Box<dyn GemmBackend>, String> {
    build_backend_with_runtime(cfg, wid, store, None)
}

/// Construct the configured backend over the coordinator's shared
/// runtime state: the plan store (every worker's core borrows plans from
/// one store) and, for native RNS cores, the execution fabric (every
/// worker's engine fans out on one shared pool under its budget).
pub fn build_backend_with_runtime(
    cfg: &CoordinatorConfig,
    wid: usize,
    store: Arc<PlanStore>,
    fabric: Option<FabricHandle>,
) -> Result<Box<dyn GemmBackend>, String> {
    let seed = cfg.seed ^ (wid as u64).wrapping_mul(0x9E37_79B9);
    match &cfg.backend {
        BackendKind::Fp32 => Ok(Box::new(Fp32Backend)),
        BackendKind::FixedPoint { bits } => {
            Ok(Box::new(FixedPointCore::new(*bits, cfg.h, NoiseModel::None, seed)))
        }
        BackendKind::Rns { bits, redundant, attempts, noise } => {
            let engine: Box<dyn ModularGemmEngine> = match fabric {
                Some(handle) => Box::new(NativeEngine::with_fabric(handle)),
                None => Box::new(NativeEngine::default()),
            };
            let core = RnsCore::with_engine_and_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed),
                engine,
                store,
            )?;
            Ok(Box::new(core))
        }
        BackendKind::RnsPjrt { bits, redundant, attempts, noise } => {
            let rt = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
            let engine = PjrtEngine::load(&rt, &cfg.artifacts_dir, *bits).map_err(|e| e.to_string())?;
            let core = RnsCore::with_engine_and_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed),
                Box::new(engine),
                store,
            )?;
            Ok(Box::new(core))
        }
    }
}

fn split_logits(all: &MatF, offset: usize, n: usize) -> MatF {
    all.slice_rows(offset, offset + n)
}

/// Read-only state every worker shares (one clone per worker thread).
struct WorkerShared {
    cfg: CoordinatorConfig,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    responder: Responder,
    done_tx: Sender<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    fabric: Option<FabricHandle>,
}

/// Per-worker cumulative-counter snapshots, so each batch reports deltas
/// into the shared metrics (multi-worker totals sum instead of
/// last-writer-wins).
#[derive(Default)]
struct WorkerCounters {
    faults: u64,
    corrected: u64,
    plans: u64,
    fast: u64,
    voted: u64,
    dac: u64,
    adc: u64,
}

/// Interleave one worker's batch stream with its control stream: control
/// messages (proactive unload, shutdown) are handled between batches —
/// ahead of any queued batches — and a `Shutdown` still drains every
/// batch already accepted before the pump returns.
fn worker_message_pump(
    rx: &Receiver<FormedBatch>,
    ctrl_rx: &Receiver<ControlMsg>,
    mut on_event: impl FnMut(WorkerEvent),
) {
    let mut batches_open = true;
    loop {
        match ctrl_rx.try_recv() {
            Ok(ControlMsg::Shutdown) => break,
            Ok(ControlMsg::Unload { model, ack }) => {
                on_event(WorkerEvent::Unload { model, ack });
                continue; // drain all pending control before the next batch
            }
            Err(TryRecvError::Empty) => {}
            Err(TryRecvError::Disconnected) => {
                if !batches_open {
                    break; // both channels gone: coordinator dropped
                }
            }
        }
        if batches_open {
            match rx.recv_timeout(CONTROL_POLL) {
                Ok(batch) => on_event(WorkerEvent::Batch(batch)),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => batches_open = false,
            }
        } else {
            // dispatcher gone: only control traffic remains, block on it
            match ctrl_rx.recv() {
                Ok(ControlMsg::Shutdown) | Err(_) => break,
                Ok(ControlMsg::Unload { model, ack }) => {
                    on_event(WorkerEvent::Unload { model, ack });
                }
            }
        }
    }
    // a shutdown must not drop batches the dispatcher already handed us
    while let Ok(batch) = rx.try_recv() {
        on_event(WorkerEvent::Batch(batch));
    }
}

fn worker_loop(
    wid: usize,
    sh: WorkerShared,
    rx: Receiver<FormedBatch>,
    ctrl_rx: Receiver<ControlMsg>,
) {
    // Backend is constructed in-thread (PJRT state is !Send), but borrows
    // the shared plan store + fabric; models come as shared Arcs from the
    // registry.
    let mut backend =
        match build_backend_with_runtime(&sh.cfg, wid, Arc::clone(&sh.store), sh.fabric.clone()) {
            Ok(b) => {
                crate::log_debug!("worker", "worker {wid} ready with backend {}", b.name());
                b
            }
            Err(e) => {
                crate::log_error!("worker", "worker {wid} backend construction failed: {e}");
                // no backend: fail every batch with the construction
                // error, but keep serving the control plane so
                // unload_model never hangs on a dead worker
                worker_message_pump(&rx, &ctrl_rx, |ev| match ev {
                    WorkerEvent::Batch(batch) => {
                        fail_batch(wid, batch, &e, &sh.responder, &sh.metrics)
                    }
                    WorkerEvent::Unload { ack, .. } => {
                        ack.send(UnloadAck { dropped: false }).ok();
                    }
                });
                return;
            }
        };
    let mut models: HashMap<String, Arc<dyn Model>> = HashMap::new();
    let mut counters = WorkerCounters::default();
    worker_message_pump(&rx, &ctrl_rx, |ev| match ev {
        WorkerEvent::Batch(batch) => {
            serve_batch(wid, &sh, backend.as_mut(), &mut models, &mut counters, batch)
        }
        WorkerEvent::Unload { model, ack } => {
            // proactive release: drop the shared-instance clone now (the
            // registry and store were already unloaded by the
            // coordinator), and let the backend forget its per-model
            // state — no request for the name is needed anymore
            let dropped = models.remove(&model).is_some();
            backend.release_model(&model);
            crate::log_debug!(
                "worker",
                "worker {wid}: control unload `{model}` (held instance: {dropped})"
            );
            ack.send(UnloadAck { dropped }).ok();
        }
    });
}

fn serve_batch(
    wid: usize,
    sh: &WorkerShared,
    backend: &mut dyn GemmBackend,
    models: &mut HashMap<String, Arc<dyn Model>>,
    counters: &mut WorkerCounters,
    batch: FormedBatch,
) {
    // tag plan lookups with the model for per-model store counters
    // (and so served plans are pinned until model unload)
    backend.set_model_tag(&batch.model);
    // fetch the shared instance through the registry every batch (one
    // mutex lock — trivial against a forward pass): this is what lets
    // `Coordinator::unload_model` take effect mid-session.  A model
    // unloaded and requested again reloads fresh, and the pointer
    // comparison below detects the new instance and re-warms it.
    let model = match sh.registry.get_or_load(&batch.model) {
        Ok(m) => m,
        Err(e) => {
            crate::log_warn!("worker", "worker {wid}: model `{}` failed to load: {e}", batch.model);
            fail_batch(wid, batch, &e, &sh.responder, &sh.metrics);
            return;
        }
    };
    let warmed = models.get(&batch.model).is_some_and(|prev| Arc::ptr_eq(prev, &model));
    if !warmed {
        // a fresh instance ends any draining state from a prior unload,
        // so this generation's plans pin again (stale rebuilds from
        // batches that raced the unload stay LRU-bounded instead of
        // leaking as pinned entries)
        sh.store.activate_model(&batch.model);
        // warm the per-layer RNS plans: the shared store deduplicates,
        // so W workers warming the same model build each plan exactly
        // once — the other W-1 warms are store hits that only adopt
        // (and charge their core's one-time weight-DAC energy)
        model.warm(backend);
        crate::log_debug!(
            "worker",
            "worker {wid}: warmed `{}` ({} layer plans adopted)",
            batch.model,
            backend.plans_built()
        );
        // replacing a stale entry also drops this worker's Arc to an
        // unloaded instance, releasing its share of the old weights
        models.insert(batch.model.clone(), Arc::clone(&model));
    }
    let picked_up = Instant::now();
    let logits = model.forward(&batch.input, backend);
    // fault counters from the RRNS core, per batch
    let (detected, corrected, fast_path, voted) = backend_fault_counts(backend);
    let batch_faults = detected.saturating_sub(counters.faults);
    counters.faults = detected;
    // all per-worker cumulative counters accumulate into the shared
    // metrics as deltas (like plans_built) so multi-worker totals sum
    // across workers instead of last-writer-wins
    let corrected_delta = corrected.saturating_sub(counters.corrected);
    counters.corrected = corrected;
    let fast_delta = fast_path.saturating_sub(counters.fast);
    counters.fast = fast_path;
    let voted_delta = voted.saturating_sub(counters.voted);
    counters.voted = voted;
    // plans adopted since the last batch: warm-time adoptions land in
    // the first delta, and a steady-state delta > 0 means a layer was
    // first seen mid-request (a warm() gap worth fixing)
    let plans_now = backend.plans_built();
    let plans_delta = plans_now.saturating_sub(counters.plans);
    counters.plans = plans_now;
    // data-converter activity, same delta discipline (deterministic
    // integer counts, so a served stream is exactly comparable to the
    // in-process path — the gateway bit-identity test relies on it)
    let (dac_now, adc_now) =
        backend.meter().map(|m| (m.dac_conversions, m.adc_conversions)).unwrap_or((0, 0));
    let dac_delta = dac_now.saturating_sub(counters.dac);
    counters.dac = dac_now;
    let adc_delta = adc_now.saturating_sub(counters.adc);
    counters.adc = adc_now;
    {
        let mut m = sh.metrics.lock().unwrap();
        m.faults_detected += batch_faults;
        m.faults_corrected += corrected_delta;
        m.decode_fast_path += fast_delta;
        m.decode_voted += voted_delta;
        m.plans_built += plans_delta;
        m.energy_dac_conversions += dac_delta;
        m.energy_adc_conversions += adc_delta;
        // the same deltas, attributed to the model this batch ran — a
        // worker serves one batch (= one model) at a time, so the
        // counter deltas since the previous batch belong to it
        m.record_model_batch(
            &batch.model,
            batch_faults,
            corrected_delta,
            fast_delta,
            voted_delta,
            plans_delta,
        );
    }
    for (req, offset) in batch.members {
        let n = req.num_samples();
        let latency = req.submitted_at.elapsed();
        let queue_time = picked_up.duration_since(req.submitted_at);
        sh.metrics.lock().unwrap().record_response(n, latency, queue_time, true);
        sh.responder.deliver(InferenceResponse {
            id: req.id,
            result: Ok(split_logits(&logits, offset, n)),
            queue_time,
            latency,
            worker: wid,
            faults_detected: batch_faults,
        });
    }
    sh.done_tx.send(wid).ok();
}

fn backend_fault_counts(backend: &dyn GemmBackend) -> (u64, u64, u64, u64) {
    backend
        .fault_stats()
        .map(|s| (s.detections, s.corrected, s.fast_path_elems, s.voted_elems))
        .unwrap_or((0, 0, 0, 0))
}

fn fail_batch(
    wid: usize,
    batch: FormedBatch,
    err: &str,
    responder: &Responder,
    metrics: &Arc<Mutex<ServingMetrics>>,
) {
    for (req, _) in batch.members {
        let latency = req.submitted_at.elapsed();
        metrics.lock().unwrap().record_response(req.num_samples(), latency, latency, false);
        responder.deliver(InferenceResponse {
            id: req.id,
            result: Err(err.to_string()),
            queue_time: latency,
            latency,
            worker: wid,
            faults_detected: 0,
        });
    }
}

/// Convenience: build an image batch from raw NHWC data.
pub fn image_batch(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Batch {
    Batch::Images(Nhwc::from_vec(n, h, w, c, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
    }

    #[test]
    fn serve_fp32_roundtrip() {
        if !have_artifacts() {
            return; // artifacts not built in this environment
        }
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        let coord = Coordinator::start(cfg);
        let mut ids = Vec::new();
        for _ in 0..5 {
            ids.push(coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1))));
        }
        let resps = coord.collect(5);
        assert_eq!(resps.len(), 5);
        for r in &resps {
            let logits = r.result.as_ref().expect("ok");
            assert_eq!((logits.rows, logits.cols), (1, 10));
        }
        let report = coord.shutdown();
        assert!(report.contains("requests=5"), "{report}");
    }

    #[test]
    fn workers_share_one_plan_store() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(
            BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
            &artifacts_dir(),
        );
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        for _ in 0..9 {
            coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
        }
        let resps = coord.collect(9);
        assert!(resps.iter().all(|r| r.result.is_ok()));
        let store = coord.plan_store();
        let stats = store.stats();
        // the mlp has 3 weight GEMMs: exactly 3 plans exist store-wide,
        // however many of the 3 workers warmed the model
        assert_eq!(stats.builds, 3, "plans deduplicated across workers");
        assert_eq!(stats.resident_plans, 3);
        let report = coord.shutdown();
        assert!(report.contains("plan store: resident=3"), "{report}");
        assert!(report.contains("plan store model=mlp:"), "{report}");
        assert!(report.contains("model=mlp: batches="), "{report}");
        // native RNS workers share one fabric and its line is reported
        assert!(report.contains("fabric: threads="), "{report}");
    }

    #[test]
    fn unknown_model_fails_gracefully() {
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        let coord = Coordinator::start(cfg);
        coord.submit("nope", Batch::Images(Nhwc::zeros(1, 2, 2, 1)));
        let r = coord.recv_timeout(Duration::from_secs(5)).expect("response");
        assert!(r.result.is_err());
        coord.shutdown();
    }

    #[test]
    fn responses_match_request_ids() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        let ids: Vec<RequestId> =
            (0..9).map(|_| coord.submit("mlp", Batch::Images(Nhwc::zeros(2, 28, 28, 1)))).collect();
        let resps = coord.collect(9);
        let mut got: Vec<RequestId> = resps.iter().map(|r| r.id).collect();
        got.sort();
        assert_eq!(got, ids);
        for r in &resps {
            assert_eq!(r.result.as_ref().unwrap().rows, 2);
        }
        coord.shutdown();
    }

    #[test]
    fn unload_without_workers_holding_the_model_is_clean() {
        // control-plane unload of a never-loaded name: no acks claim a
        // drop, no plans evicted, the coordinator keeps serving
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        let coord = Coordinator::start(cfg);
        assert_eq!(coord.unload_model("mlp"), 0);
        coord.submit("nope", Batch::Images(Nhwc::zeros(1, 2, 2, 1)));
        assert!(coord.recv_timeout(Duration::from_secs(5)).is_some());
        let report = coord.shutdown();
        assert!(report.contains("unloads: proactive=1 worker-releases=0"), "{report}");
    }
}
