//! The serving coordinator: a dispatcher thread (dynamic batcher + round-
//! robin tile scheduler) feeding a pool of worker threads, each owning a
//! simulated analog core over *shared* read-only state: one
//! `ModelRegistry` (every worker clones `Arc<dyn Model>` — weights exist
//! once), one `PlanStore` (every layer's `RnsPlan` exists once, whichever
//! worker builds it first; `Model::warm` from W workers deduplicates to
//! one build per layer), and — for native RNS backends — one
//! `ExecutionFabric` (every worker's engine fans GEMM shards onto one
//! process-wide `WorkerPool` under a per-worker helper budget, so total
//! fan-out threads are bounded by cores − 1 regardless of W).
//!
//! Engines wrapping PJRT state are not `Send`, so every worker constructs
//! its own backend *inside* its thread — mirroring how a real deployment
//! pins one accelerator context per worker.  The RRNS detect→recompute
//! loop (paper §IV) runs inside the core; its fault counters are merged
//! into the serving metrics — globally and per model — and the plan
//! store's and fabric's counters land in the shutdown report.
//!
//! **Control plane.**  Each worker *slot* owns a condvar'd `Mailbox`
//! carrying both its batch stream and its control stream (one wait, no
//! polling; control outranks batches).  `Coordinator::unload_model` uses
//! it to *proactively* release worker-held state — each worker drops its
//! cached `Arc<dyn Model>` and stale plan adoptions and acks — and
//! `shutdown` drains workers through the same mailbox (a `Shutdown`
//! control message; queued batches still complete first).
//!
//! **Supervision (PR 6).**  The paper's detect→retry→recover story,
//! lifted from residue channels to worker threads.  A supervisor thread
//! watches for two failure shapes:
//!
//!   * **death** — a panic anywhere in the batch path is caught at the
//!     worker loop boundary and reported as `WorkerDown` together with
//!     the in-flight batch;
//!   * **stall** — each worker heartbeats around its forward pass; a
//!     busy worker whose heartbeat goes stale past `stall_timeout` is
//!     declared stalled.
//!
//! Recovery is the same for both: the slot's mailbox generation is
//! bumped (retiring the old thread — a stalled-but-alive zombie finishes
//! its batch, delivers it exactly once, and exits on the next `recv`)
//! and a replacement thread is spawned **on the same mailbox**, so
//! queued batches and control messages carry over untouched.  The
//! replacement re-warms plans through the build-once `PlanStore` (cheap:
//! warms are store hits that only adopt).  A dead worker's in-flight
//! batch is **redispatched** to a healthy slot — inference is pure, so
//! the replay is bit-identical under `NoiseModel::None` — unless it has
//! already crashed `poison_threshold` workers, in which case it is
//! quarantined with a typed `Poisoned` reject instead of fueling a crash
//! loop.  Requests may carry a **deadline** (per-request or the server
//! default): expired requests are failed with a typed
//! `DeadlineExceeded` — in the dispatcher queue, at batch pickup, or at
//! delivery — instead of burning analog-core time on answers nobody is
//! waiting for.  All of it is driven deterministically by the seeded
//! positional `ChaosSpec` (chaos.rs) and surfaced in the report's
//! `supervision:` line.
//!
//! Counter discipline under crashes: a worker flushes its per-batch
//! counter deltas into the shared metrics only *after* a batch
//! completes, so a crashed worker's partial forward never lands — the
//! redispatched replay is counted exactly once and `decode:`/`faults:`/
//! adc-conversion totals stay bit-identical to a crash-free run.  (DAC
//! conversions and plan adoptions differ: the replacement's re-warm
//! legitimately recharges the weight DACs.)

use std::any::Any;
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analog::{
    FixedPointCore, Fp32Backend, GemmBackend, NoiseModel, RnsCore, RnsCoreConfig, StageMicros,
};
use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher, FormedBatch};
use crate::coordinator::chaos::{ChaosAction, ChaosSpec, WorkerChaos};
use crate::coordinator::mailbox::{Mail, Mailbox};
use crate::coordinator::metrics::{
    GatewayReport, RequestTrace, ServingMetrics, DEFAULT_TRACE_SLOTS,
};
use crate::util::metrics::MetricRegistry;
use crate::util::trace::{self, Span, SpanBuffer, TraceCollector};
use crate::coordinator::request::{
    InferenceRequest, InferenceResponse, RequestId, ServeError, ServeErrorKind,
};
use crate::coordinator::router::RoutingKind;
use crate::nn::models::{Batch, Model, ModelRegistry};
use crate::runtime::fabric::{ExecutionFabric, FabricHandle};
use crate::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use crate::runtime::{ModularGemmEngine, NativeEngine};
use crate::store::{PlanStore, DEFAULT_UNTAGGED_CAPACITY};
use crate::tensor::{MatF, Nhwc};

/// Which simulated hardware the workers run.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// FP32 reference (no analog hardware).
    Fp32,
    /// Regular fixed-point analog core (b_adc = bits).
    FixedPoint { bits: u32 },
    /// RNS analog core; `redundant > 0` enables the RRNS retry loop.
    Rns { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
    /// RNS core executing through the AOT pallas kernel via PJRT.
    RnsPjrt { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendKind,
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub artifacts_dir: String,
    /// Analog array height.
    pub h: usize,
    pub seed: u64,
    /// Worker routing policy (round-robin or least-outstanding).
    pub routing: RoutingKind,
    /// LRU bound for *untagged* plans in the shared plan store (served
    /// models' plans are tagged and pinned until unload).
    pub plan_store_capacity: usize,
    /// Total thread budget for the shared execution fabric (native RNS
    /// backends): 0 = auto (`RNS_NATIVE_THREADS`, else core count).
    pub fabric_threads: usize,
    /// Injected process faults (tests / chaos smoke); empty = none.
    pub chaos: ChaosSpec,
    /// Heartbeat staleness after which a *busy* worker is declared
    /// stalled and its slot handed to a replacement thread.
    pub stall_timeout: Duration,
    /// Worker crashes a single batch may cause before it is quarantined
    /// with a typed `Poisoned` reject instead of being redispatched.
    pub poison_threshold: u32,
    /// Deadline applied to requests that carry none; `None` = unlimited.
    pub default_deadline: Option<Duration>,
    /// Conversion-avoiding sparse execution on RNS backends (see
    /// `RnsCoreConfig::sparse_capture`): skip DAC/ADC/CRT work for zero
    /// activations and report it as `skipped-dac=`/`skipped-adc=` on the
    /// `energy:` metrics line.  Default off for RNG-stream compatibility.
    pub sparse_capture: bool,
    /// Slowest-request traces kept in the bounded ring (`trace:` report
    /// lines and the `Traces` wire frame); 0 disables tracing — both the
    /// one-line ring summaries and the span trees below.
    pub trace_slots: usize,
    /// Fraction of requests sampled into full span trees (see
    /// `util::trace::TraceCollector`), decided by a seeded hash so runs
    /// are reproducible.  0 (the default) records spans only for
    /// requests that arrive with a client-chosen trace id or fail with
    /// `DeadlineExceeded`/`Poisoned`.
    pub trace_sample: f64,
}

impl CoordinatorConfig {
    pub fn new(backend: BackendKind, artifacts_dir: &str) -> Self {
        CoordinatorConfig {
            backend,
            workers: 2,
            batcher: BatcherConfig::default(),
            artifacts_dir: artifacts_dir.to_string(),
            h: 128,
            seed: 0,
            routing: RoutingKind::default(),
            plan_store_capacity: DEFAULT_UNTAGGED_CAPACITY,
            fabric_threads: 0,
            chaos: ChaosSpec::default(),
            stall_timeout: Duration::from_secs(30),
            poison_threshold: 2,
            default_deadline: None,
            sparse_capture: false,
            trace_slots: DEFAULT_TRACE_SLOTS,
            trace_sample: 0.0,
        }
    }
}

/// How long `unload_model` waits for each worker's release ack before
/// giving up (a worker mid-forward acks after its current batch).
const UNLOAD_ACK_TIMEOUT: Duration = Duration::from_secs(30);

/// Control-plane messages delivered alongside the batch stream.
enum ControlMsg {
    /// Drop the cached `Arc<dyn Model>` and per-model backend state for
    /// `model`; reply on `ack`.
    Unload { model: String, ack: Sender<UnloadAck> },
    /// Finish every already-queued batch, then exit.
    Shutdown,
}

/// One worker's reply to `ControlMsg::Unload`.
struct UnloadAck {
    /// Whether the worker actually held (and dropped) a cached instance.
    dropped: bool,
}

/// One worker slot's inbox: batches + control through a single condvar.
type WorkerBox = Mailbox<FormedBatch, ControlMsg>;

/// Messages from worker threads (and `shutdown`) to the supervisor.
enum SupervisorMsg {
    /// A worker thread died.  `gen` is the sender's mailbox generation —
    /// a stale `gen` means a superseded zombie died, whose slot already
    /// has a live owner (its batch still needs a fate; the slot does
    /// not).  `batch` is the in-flight batch, if it died holding one.
    WorkerDown { wid: usize, gen: u64, batch: Option<FormedBatch>, error: String },
    /// Shutdown barrier: reply once every earlier message is processed.
    Sync(Sender<()>),
    /// Exit the supervisor loop.
    Stop,
}

/// Per-request response routing callback (registered by
/// `CoordinatorHandle::submit_routed`; the TCP gateway's session threads
/// use it to steer each reply back to the session that asked).
type DeliverFn = Box<dyn FnOnce(InferenceResponse) + Send>;

/// Request id → delivery callback for routed submissions.
type ResponseRoutes = Arc<Mutex<HashMap<RequestId, DeliverFn>>>;

/// How workers hand responses back: a routed request's callback wins,
/// everything else lands on the coordinator's default response channel
/// (the in-process `recv`/`collect` API).
#[derive(Clone)]
struct Responder {
    default_tx: Sender<InferenceResponse>,
    routes: ResponseRoutes,
}

impl Responder {
    fn deliver(&self, resp: InferenceResponse) {
        // take the callback out under the lock, call it after: a delivery
        // callback may itself take locks (gateway latency percentiles)
        let cb = self.routes.lock().unwrap().remove(&resp.id);
        match cb {
            Some(cb) => cb(resp),
            None => {
                self.default_tx.send(resp).ok();
            }
        }
    }
}

/// One worker slot's supervision state.  The mailbox and chaos counters
/// are per-*slot* (they survive respawns: queued work carries over and
/// positional chaos counts never reset); the health snapshot is
/// per-*thread* (swapped on respawn so a zombie's late heartbeats are
/// invisible).
struct WorkerSlot {
    mailbox: Arc<WorkerBox>,
    health: Mutex<Arc<WorkerHealth>>,
    chaos: Arc<Mutex<WorkerChaos>>,
}

/// One worker thread's liveness signal: a microsecond heartbeat plus a
/// busy flag.  Only a *busy* worker can stall — an idle worker parks on
/// its mailbox condvar without beating, which is healthy.
struct WorkerHealth {
    epoch: Instant,
    beat_us: AtomicU64,
    busy: AtomicBool,
}

impl WorkerHealth {
    fn fresh() -> Arc<Self> {
        let h = WorkerHealth {
            epoch: Instant::now(),
            beat_us: AtomicU64::new(0),
            busy: AtomicBool::new(false),
        };
        h.beat();
        Arc::new(h)
    }

    fn beat(&self) {
        self.beat_us.store(self.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    fn set_busy(&self, busy: bool) {
        self.busy.store(busy, Ordering::Relaxed);
        self.beat();
    }

    fn stalled(&self, timeout: Duration) -> bool {
        if !self.busy.load(Ordering::Relaxed) {
            return false;
        }
        let last = Duration::from_micros(self.beat_us.load(Ordering::Relaxed));
        self.epoch.elapsed().saturating_sub(last) > timeout
    }
}

/// Everything needed to (re)spawn a worker thread on a slot — held by
/// `Coordinator::start` for the initial fleet and by the supervisor for
/// replacements.
#[derive(Clone)]
struct WorkerSpawner {
    cfg: CoordinatorConfig,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    responder: Responder,
    done_tx: Sender<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    fabric: Option<Arc<ExecutionFabric>>,
    slots: Arc<Vec<WorkerSlot>>,
    sup_tx: Sender<SupervisorMsg>,
    collector: Arc<TraceCollector>,
}

impl WorkerSpawner {
    /// Spawn a worker thread owning slot `wid` at mailbox generation
    /// `gen`, installing a fresh health snapshot for it.  Panics
    /// anywhere in the thread are caught at this boundary and reported
    /// to the supervisor (batch-path panics are caught closer in, with
    /// the in-flight batch attached).
    fn spawn(&self, wid: usize, gen: u64) -> JoinHandle<()> {
        let health = WorkerHealth::fresh();
        *self.slots[wid].health.lock().unwrap() = Arc::clone(&health);
        let sh = WorkerShared {
            cfg: self.cfg.clone(),
            store: Arc::clone(&self.store),
            registry: Arc::clone(&self.registry),
            responder: self.responder.clone(),
            done_tx: self.done_tx.clone(),
            metrics: Arc::clone(&self.metrics),
            fabric: self.fabric.as_ref().map(|f| f.handle()),
            sup_tx: self.sup_tx.clone(),
            mailbox: Arc::clone(&self.slots[wid].mailbox),
            chaos: Arc::clone(&self.slots[wid].chaos),
            health,
            collector: Arc::clone(&self.collector),
        };
        let sup_tx = self.sup_tx.clone();
        std::thread::Builder::new()
            .name(format!("rns-worker-{wid}"))
            .spawn(move || {
                if let Err(payload) =
                    panic::catch_unwind(AssertUnwindSafe(move || worker_loop(wid, gen, sh)))
                {
                    // a panic outside the batch path (control handling,
                    // backend teardown): no batch to salvage, but the
                    // slot still needs a replacement
                    sup_tx
                        .send(SupervisorMsg::WorkerDown {
                            wid,
                            gen,
                            batch: None,
                            error: panic_text(payload.as_ref()),
                        })
                        .ok();
                }
            })
            .expect("spawn worker")
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    /// Shared with every `CoordinatorHandle`; `shutdown` takes the inner
    /// sender so *all* handles see the closed door at once (otherwise a
    /// live gateway handle would keep the dispatcher alive forever).
    submit_tx: Arc<Mutex<Option<Sender<InferenceRequest>>>>,
    resp_rx: Receiver<InferenceResponse>,
    next_id: Arc<AtomicU64>,
    routes: ResponseRoutes,
    dispatcher: Option<JoinHandle<()>>,
    supervisor: Option<JoinHandle<()>>,
    sup_tx: Sender<SupervisorMsg>,
    /// Worker thread handles; the supervisor appends replacements here,
    /// so `shutdown` joins in a take-all loop instead of a single pass.
    worker_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    slots: Arc<Vec<WorkerSlot>>,
    /// Set by `shutdown` before the control fan-out; the supervisor
    /// redispatches crashed batches to the crashed slot itself during a
    /// drain (other slots may already have exited).
    shutting_down: Arc<AtomicBool>,
    default_deadline: Option<Duration>,
    metrics: Arc<Mutex<ServingMetrics>>,
    /// Shared read-only plan store (one `RnsPlan` per layer across all
    /// workers); its counters land in the shutdown report.
    store: Arc<PlanStore>,
    /// Shared load-once model instances (one weight copy across workers).
    registry: Arc<ModelRegistry>,
    /// Shared execution fabric (native RNS backends only): one pool of
    /// fan-out threads for all workers, with per-worker budgets.
    fabric: Option<Arc<ExecutionFabric>>,
    /// End-to-end span-trace assembly (sampled requests + forced
    /// failures); shared by every tier through handles.
    collector: Arc<TraceCollector>,
    started: Instant,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (submit_tx, submit_rx) = mpsc::channel::<InferenceRequest>();
        let (resp_tx, resp_rx) = mpsc::channel::<InferenceResponse>();
        let (done_tx, done_rx) = mpsc::channel::<usize>();
        let (sup_tx, sup_rx) = mpsc::channel::<SupervisorMsg>();
        let metrics = Arc::new(Mutex::new({
            let mut m = ServingMetrics::default();
            m.set_trace_capacity(cfg.trace_slots);
            m
        }));
        // built once at startup, handed to every worker: the store is the
        // cross-worker plan memory, the registry the cross-worker
        // weights, the fabric the cross-worker thread budget
        let store = Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity));
        let registry = Arc::new(ModelRegistry::new(&cfg.artifacts_dir));
        let fabric = match &cfg.backend {
            BackendKind::Rns { .. } => Some(Arc::new(if cfg.fabric_threads > 0 {
                ExecutionFabric::with_threads(cfg.fabric_threads, cfg.workers.max(1))
            } else {
                ExecutionFabric::for_workers(cfg.workers.max(1))
            })),
            // FP32 / fixed-point / PJRT backends never touch the native
            // parallel engine — no fan-out threads to share
            _ => None,
        };

        let routes: ResponseRoutes = Arc::new(Mutex::new(HashMap::new()));
        let responder = Responder { default_tx: resp_tx, routes: Arc::clone(&routes) };
        // span-trace assembly shares the ring's slot budget: trace_slots=0
        // disables both views, and both keep the slowest N
        let collector =
            Arc::new(TraceCollector::new(cfg.trace_slots, cfg.trace_sample, cfg.seed));

        let nworkers = cfg.workers.max(1);
        let slots: Arc<Vec<WorkerSlot>> = Arc::new(
            (0..nworkers)
                .map(|wid| WorkerSlot {
                    mailbox: Arc::new(WorkerBox::new()),
                    health: Mutex::new(WorkerHealth::fresh()),
                    chaos: cfg.chaos.for_worker(wid),
                })
                .collect(),
        );
        let spawner = WorkerSpawner {
            cfg: cfg.clone(),
            store: Arc::clone(&store),
            registry: Arc::clone(&registry),
            responder: responder.clone(),
            done_tx,
            metrics: Arc::clone(&metrics),
            fabric: fabric.as_ref().map(Arc::clone),
            slots: Arc::clone(&slots),
            sup_tx: sup_tx.clone(),
            collector: Arc::clone(&collector),
        };
        let worker_handles = Arc::new(Mutex::new(Vec::new()));
        {
            let mut handles = worker_handles.lock().unwrap();
            for wid in 0..nworkers {
                handles.push(spawner.spawn(wid, slots[wid].mailbox.generation()));
            }
        }

        let shutting_down = Arc::new(AtomicBool::new(false));
        let sup_ctx = SupervisorCtx {
            spawner,
            worker_handles: Arc::clone(&worker_handles),
            shutting_down: Arc::clone(&shutting_down),
        };
        let supervisor = std::thread::Builder::new()
            .name("rns-supervisor".into())
            .spawn(move || supervisor_loop(sup_ctx, sup_rx))
            .expect("spawn supervisor");

        let mailboxes: Vec<Arc<WorkerBox>> =
            slots.iter().map(|s| Arc::clone(&s.mailbox)).collect();
        let batcher_cfg = cfg.batcher;
        let routing = cfg.routing;
        let metrics_d = Arc::clone(&metrics);
        let responder_d = responder.clone();
        let collector_d = Arc::clone(&collector);
        let dispatcher = std::thread::Builder::new()
            .name("rns-dispatcher".into())
            .spawn(move || {
                dispatcher_loop(
                    submit_rx,
                    mailboxes,
                    batcher_cfg,
                    routing,
                    done_rx,
                    metrics_d,
                    responder_d,
                    collector_d,
                )
            })
            .expect("spawn dispatcher");

        Coordinator {
            submit_tx: Arc::new(Mutex::new(Some(submit_tx))),
            resp_rx,
            next_id: Arc::new(AtomicU64::new(1)),
            routes,
            dispatcher: Some(dispatcher),
            supervisor: Some(supervisor),
            sup_tx,
            worker_handles,
            slots,
            shutting_down,
            default_deadline: cfg.default_deadline,
            metrics,
            store,
            registry,
            fabric,
            collector,
            started: Instant::now(),
        }
    }

    /// A clonable, thread-safe handle onto this coordinator: submit with
    /// per-request response routing, load/unload models, and render the
    /// live metrics report.  This is the surface the TCP gateway's
    /// acceptor and session threads hold (the `Coordinator` itself owns
    /// the response receiver and cannot be shared).
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle {
            submit_tx: Arc::clone(&self.submit_tx),
            next_id: Arc::clone(&self.next_id),
            routes: Arc::clone(&self.routes),
            metrics: Arc::clone(&self.metrics),
            store: Arc::clone(&self.store),
            registry: Arc::clone(&self.registry),
            fabric: self.fabric.as_ref().map(Arc::clone),
            slots: Arc::clone(&self.slots),
            collector: Arc::clone(&self.collector),
            default_deadline: self.default_deadline,
            started: self.started,
        }
    }

    /// The span-trace collector (tests and in-process tooling; the
    /// gateway reaches it through its `CoordinatorHandle`).
    pub fn trace_collector(&self) -> Arc<TraceCollector> {
        Arc::clone(&self.collector)
    }

    /// The shared plan store (one `Arc<RnsPlan>` per layer across all
    /// workers).  Exposed for tests and ops tooling.
    pub fn plan_store(&self) -> Arc<PlanStore> {
        Arc::clone(&self.store)
    }

    /// The shared model registry (one weight copy across all workers).
    pub fn model_registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// The shared execution fabric, if this backend uses one (native RNS
    /// cores).  Exposed so tests can assert the process-wide thread
    /// bound and ops tooling can read utilization.
    pub fn fabric(&self) -> Option<Arc<ExecutionFabric>> {
        self.fabric.as_ref().map(Arc::clone)
    }

    /// Drop a model's shared weights, evict its plans from the store,
    /// and — through the control plane — make every worker release its
    /// cached `Arc<dyn Model>` and stale plan adoptions *now*, without
    /// waiting for the name to be requested again.
    ///
    /// Ordering: the store unloads first (the name starts draining, so a
    /// batch racing the unload cannot re-pin dead-allocation plans),
    /// then the registry, then the control fan-out.  Each worker acks
    /// after its current batch at the latest; once every worker has
    /// acked, nothing can reference the old generation anymore, so the
    /// store's draining state is ended here (keyed off the acks) instead
    /// of waiting for the next warm's `activate_model`.  If an ack times
    /// out the name stays draining — the conservative pre-control-plane
    /// behavior.  Returns how many plans were evicted.
    pub fn unload_model(&self, name: &str) -> usize {
        unload_model_via(&self.store, &self.registry, &self.slots, &self.metrics, name)
    }

    /// Submit a request; returns its id immediately.  The server default
    /// deadline applies, if one is configured.
    pub fn submit(&self, model: &str, input: Batch) -> RequestId {
        self.submit_with_deadline(model, input, None)
    }

    /// Submit with an explicit deadline budget (`None` falls back to the
    /// configured server default).
    pub fn submit_with_deadline(
        &self,
        model: &str,
        input: Batch,
        deadline: Option<Duration>,
    ) -> RequestId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let deadline = deadline.or(self.default_deadline).map(|d| Instant::now() + d);
        let req = InferenceRequest::new(id, model, input).with_deadline(deadline);
        self.submit_tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("coordinator running")
            .send(req)
            .expect("dispatcher alive");
        id
    }

    /// Blocking receive of the next completed response.
    pub fn recv(&self) -> Option<InferenceResponse> {
        self.resp_rx.recv().ok()
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Option<InferenceResponse> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Drain exactly `n` responses (in completion order).
    pub fn collect(&self, n: usize) -> Vec<InferenceResponse> {
        (0..n).filter_map(|_| self.recv()).collect()
    }

    /// Stop accepting requests, drain workers through the control plane,
    /// and return the final report (plan store, fabric, and per-model
    /// counters included).  Crashes *during* the drain are still
    /// recovered: the join loop below re-checks for replacement threads
    /// (and syncs with the supervisor) until the fleet is truly quiet.
    pub fn shutdown(mut self) -> String {
        // taking the shared Option drops the one real sender, so every
        // CoordinatorHandle clone is closed too and the dispatcher sees
        // the channel disconnect
        self.submit_tx.lock().unwrap().take();
        if let Some(d) = self.dispatcher.take() {
            d.join().ok();
        }
        // every batch is now queued at some worker: drain via the control
        // plane (workers finish their queues before exiting).  The flag
        // goes first so any concurrent respawn drains its slot too.
        self.shutting_down.store(true, Ordering::SeqCst);
        for slot in self.slots.iter() {
            slot.mailbox.push_control(ControlMsg::Shutdown);
        }
        loop {
            let handles: Vec<JoinHandle<()>> =
                self.worker_handles.lock().unwrap().drain(..).collect();
            if handles.is_empty() {
                // every joined thread sent its WorkerDown (if any) before
                // exiting; the sync barrier makes the supervisor process
                // them — any replacement it spawned is visible after it
                let (ack_tx, ack_rx) = mpsc::channel();
                if self.sup_tx.send(SupervisorMsg::Sync(ack_tx)).is_ok() {
                    ack_rx.recv_timeout(Duration::from_secs(10)).ok();
                }
                if self.worker_handles.lock().unwrap().is_empty() {
                    break;
                }
            } else {
                for h in handles {
                    h.join().ok();
                }
            }
        }
        self.sup_tx.send(SupervisorMsg::Stop).ok();
        if let Some(s) = self.supervisor.take() {
            s.join().ok();
        }
        let wall = self.started.elapsed();
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        if let Some(f) = &self.fabric {
            m.set_fabric(f.stats());
        }
        m.report(wall)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // `shutdown(self)` already ran if both threads were taken; a
        // plain drop must still unpark the fleet (mailbox waits don't
        // end with a channel disconnect the way mpsc receivers did)
        if self.dispatcher.is_none() && self.supervisor.is_none() {
            return;
        }
        self.submit_tx.lock().unwrap().take();
        if let Some(d) = self.dispatcher.take() {
            d.join().ok();
        }
        self.shutting_down.store(true, Ordering::SeqCst);
        for slot in self.slots.iter() {
            slot.mailbox.push_control(ControlMsg::Shutdown);
        }
        self.sup_tx.send(SupervisorMsg::Stop).ok();
        if let Some(s) = self.supervisor.take() {
            s.join().ok();
        }
        // workers drain in the background; their handles drop detached
    }
}

/// Clonable, `Send + Sync` view onto a running coordinator — the surface
/// gateway session threads (and any other concurrent submitter) hold.
/// Every clone shares the coordinator's submit door: after
/// `Coordinator::shutdown` takes the sender, `submit_routed` on any
/// handle returns an error instead of hanging.
#[derive(Clone)]
pub struct CoordinatorHandle {
    submit_tx: Arc<Mutex<Option<Sender<InferenceRequest>>>>,
    next_id: Arc<AtomicU64>,
    routes: ResponseRoutes,
    metrics: Arc<Mutex<ServingMetrics>>,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    fabric: Option<Arc<ExecutionFabric>>,
    slots: Arc<Vec<WorkerSlot>>,
    collector: Arc<TraceCollector>,
    default_deadline: Option<Duration>,
    started: Instant,
}

impl CoordinatorHandle {
    /// Submit with per-request response routing: `deliver` is invoked
    /// (once, from the worker that served the batch) with this request's
    /// response instead of the response landing on `Coordinator::recv`.
    /// Registration happens before the send, so a response can never
    /// race past its route.
    pub fn submit_routed(
        &self,
        model: &str,
        input: Batch,
        deliver: impl FnOnce(InferenceResponse) + Send + 'static,
    ) -> Result<RequestId, String> {
        self.submit_routed_with_deadline(model, input, None, deliver)
    }

    /// `submit_routed` with an explicit deadline budget (`None` falls
    /// back to the configured server default) — the gateway's Infer
    /// path, carrying the frame's `deadline_ms` field.
    pub fn submit_routed_with_deadline(
        &self,
        model: &str,
        input: Batch,
        deadline: Option<Duration>,
        deliver: impl FnOnce(InferenceResponse) + Send + 'static,
    ) -> Result<RequestId, String> {
        self.submit_routed_traced(model, input, deadline, 0, deliver)
    }

    /// `submit_routed_with_deadline` carrying a span-trace id (0 =
    /// unsampled): the request's queue and per-stage spans are recorded
    /// against it by the dispatcher and the serving worker.
    pub fn submit_routed_traced(
        &self,
        model: &str,
        input: Batch,
        deadline: Option<Duration>,
        trace: u64,
        deliver: impl FnOnce(InferenceResponse) + Send + 'static,
    ) -> Result<RequestId, String> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.routes.lock().unwrap().insert(id, Box::new(deliver));
        let deadline = deadline.or(self.default_deadline).map(|d| Instant::now() + d);
        let sent = match self.submit_tx.lock().unwrap().as_ref() {
            Some(tx) => {
                let req = InferenceRequest::new(id, model, input)
                    .with_deadline(deadline)
                    .with_trace(trace);
                tx.send(req).is_ok()
            }
            None => false,
        };
        if !sent {
            self.routes.lock().unwrap().remove(&id);
            return Err("coordinator is shut down".into());
        }
        Ok(id)
    }

    /// Whether the coordinator still accepts submissions (`/readyz`):
    /// false once `Coordinator::shutdown` has taken the submit door.
    pub fn is_serving(&self) -> bool {
        self.submit_tx.lock().unwrap().is_some()
    }

    /// The shared span-trace collector (gateway sampling, `/trace`
    /// rendering, the `TraceSpans` wire frame).
    pub fn trace_collector(&self) -> Arc<TraceCollector> {
        Arc::clone(&self.collector)
    }

    /// Load a model into the shared registry now (workers still warm
    /// their plans on first batch).  An explicit gateway `LoadModel`
    /// frame pays the filesystem load before traffic arrives.
    pub fn load_model(&self, name: &str) -> Result<(), String> {
        self.registry.get_or_load(name).map(|_| ())
    }

    /// Proactive model unload through the worker control plane; see
    /// `Coordinator::unload_model`.  Returns evicted plan count.
    pub fn unload_model(&self, name: &str) -> usize {
        unload_model_via(&self.store, &self.registry, &self.slots, &self.metrics, name)
    }

    /// Render the live metrics report (same shape as the shutdown
    /// report, including the plan-store and fabric blocks) without
    /// stopping anything — the `Stats` frame and `GET /metrics` body.
    pub fn live_report(&self) -> String {
        let wall = self.started.elapsed();
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        if let Some(f) = &self.fabric {
            m.set_fabric(f.stats());
        }
        m.report(wall)
    }

    /// Attach the gateway's session/frame counters so they render in
    /// every subsequent report (live and shutdown).
    pub fn set_gateway_report(&self, g: GatewayReport) {
        self.metrics.lock().unwrap().set_gateway(g);
    }

    /// The coordinator's shared metric registry — the gateway registers
    /// its own counters here so one registry feeds the report *and* the
    /// Prometheus exposition.
    pub fn metric_registry(&self) -> Arc<MetricRegistry> {
        self.metrics.lock().unwrap().registry()
    }

    /// Render the registry as Prometheus text exposition
    /// (`text/plain; version=0.0.4`) — the body of
    /// `GET /metrics?format=prometheus`.  Snapshot-backed blocks (plan
    /// store, fabric) are refreshed first, so a quiescent scrape agrees
    /// exactly with `live_report`'s legacy lines.
    pub fn prometheus_report(&self) -> String {
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        if let Some(f) = &self.fabric {
            m.set_fabric(f.stats());
        }
        m.render_prometheus()
    }

    /// The slowest-request trace block (the `Traces` frame's reply).
    pub fn traces_report(&self) -> String {
        self.metrics.lock().unwrap().traces_report()
    }

    /// The span-trace summary block (the `TraceSpans` frame's reply):
    /// greppable `span-trace:` lines, slowest first.
    pub fn trace_spans_report(&self) -> String {
        self.collector.summary()
    }
}

/// Shared implementation of the proactive unload (used by the owning
/// `Coordinator` and by every `CoordinatorHandle`): store unload first
/// (the name starts draining), then registry, then the control fan-out,
/// then end the draining state once every worker acked.  Mailboxes are
/// per-slot, so an unload racing a respawn still lands: the replacement
/// thread inherits the queued `Unload` and acks it.
fn unload_model_via(
    store: &Arc<PlanStore>,
    registry: &Arc<ModelRegistry>,
    slots: &Arc<Vec<WorkerSlot>>,
    metrics: &Arc<Mutex<ServingMetrics>>,
    name: &str,
) -> usize {
    let evicted = store.unload_model(name);
    registry.unload(name);
    let (ack_tx, ack_rx) = mpsc::channel();
    let mut sent = 0usize;
    for slot in slots.iter() {
        slot.mailbox
            .push_control(ControlMsg::Unload { model: name.to_string(), ack: ack_tx.clone() });
        sent += 1;
    }
    drop(ack_tx);
    let mut acked = 0usize;
    let mut released = 0u64;
    while acked < sent {
        match ack_rx.recv_timeout(UNLOAD_ACK_TIMEOUT) {
            Ok(ack) => {
                acked += 1;
                if ack.dropped {
                    released += 1;
                }
            }
            Err(_) => break,
        }
    }
    if acked == sent {
        // every worker released: a later request for the name loads
        // a fresh instance and pins fresh plans as usual
        store.activate_model(name);
    } else {
        crate::log_warn!(
            "coordinator",
            "unload `{name}`: only {acked}/{sent} workers acked; name stays draining"
        );
    }
    metrics.lock().unwrap().record_unload(released);
    evicted
}

/// The supervisor's working set: how to respawn, where the thread
/// handles live, and whether a drain is in progress.
struct SupervisorCtx {
    spawner: WorkerSpawner,
    worker_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    shutting_down: Arc<AtomicBool>,
}

/// Detect → respawn → redispatch.  Death arrives as `WorkerDown` (the
/// panic boundary around the batch path sends it with the in-flight
/// batch attached); stalls are found by scanning heartbeats on the
/// receive timeout, which doubles as the scan cadence.
fn supervisor_loop(ctx: SupervisorCtx, sup_rx: Receiver<SupervisorMsg>) {
    let stall_timeout = ctx.spawner.cfg.stall_timeout;
    let poll = (stall_timeout / 4).clamp(Duration::from_millis(10), Duration::from_secs(1));
    loop {
        match sup_rx.recv_timeout(poll) {
            Ok(SupervisorMsg::WorkerDown { wid, gen, batch, error }) => {
                handle_worker_down(&ctx, wid, gen, batch, error);
            }
            Ok(SupervisorMsg::Sync(ack)) => {
                ack.send(()).ok();
            }
            Ok(SupervisorMsg::Stop) | Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => scan_for_stalls(&ctx, stall_timeout),
        }
    }
}

/// A worker thread died.  Retire its generation, decide its in-flight
/// batch's fate (redispatch vs quarantine), and bring up a replacement
/// on the same mailbox.  A stale `gen` means the sender was an already-
/// superseded zombie: its batch still needs a fate, but the slot already
/// has a live owner, so no respawn.
fn handle_worker_down(
    ctx: &SupervisorCtx,
    wid: usize,
    gen: u64,
    batch: Option<FormedBatch>,
    error: String,
) {
    let slots = &ctx.spawner.slots;
    let draining = ctx.shutting_down.load(Ordering::SeqCst);
    let current = slots[wid].mailbox.generation() == gen;
    crate::log_warn!(
        "supervisor",
        "worker {wid} died{}: {error}",
        if current { "" } else { " (superseded zombie)" }
    );
    // decide the batch's fate first, so a same-slot redispatch is queued
    // before the replacement starts consuming
    if let Some(mut batch) = batch {
        batch.crashes += 1;
        if batch.crashes >= ctx.spawner.cfg.poison_threshold {
            crate::log_warn!(
                "supervisor",
                "batch for `{}` crashed {} workers; quarantined",
                batch.model,
                batch.crashes
            );
            ctx.spawner.metrics.lock().unwrap().poisoned.inc();
            let err = ServeError::new(
                ServeErrorKind::Poisoned,
                format!(
                    "batch quarantined after crashing {} workers (last error: {error})",
                    batch.crashes
                ),
            );
            fail_batch(
                wid,
                &batch,
                err,
                &ctx.spawner.responder,
                &ctx.spawner.metrics,
                &ctx.spawner.collector,
            );
        } else {
            // inference is pure: replaying the batch on a healthy slot
            // is bit-identical (under NoiseModel::None).  During a drain
            // the batch goes back to the *crashed* slot — other slots
            // may already have drained and exited, while this slot is
            // guaranteed a replacement (and a Shutdown) below.
            let target = if !draining && slots.len() > 1 { (wid + 1) % slots.len() } else { wid };
            crate::log_warn!(
                "supervisor",
                "redispatching crashed batch for `{}` to worker {target} (crash {})",
                batch.model,
                batch.crashes
            );
            ctx.spawner.metrics.lock().unwrap().redispatched.inc();
            slots[target].mailbox.push_batch(batch);
        }
    }
    if current {
        let next_gen = slots[wid].mailbox.bump_generation();
        ctx.spawner.metrics.lock().unwrap().respawns.inc();
        let handle = ctx.spawner.spawn(wid, next_gen);
        ctx.worker_handles.lock().unwrap().push(handle);
        if draining {
            // the dead thread may already have consumed its Shutdown;
            // make sure the replacement drains too (extras are harmless)
            slots[wid].mailbox.push_control(ControlMsg::Shutdown);
        }
    }
}

/// Declare stalled any busy worker whose heartbeat went stale, and hand
/// its slot to a replacement.  The stalled thread is *not* killed (Rust
/// threads can't be) and its batch is *not* redispatched: if it ever
/// wakes it delivers exactly once, then exits on the generation check.
/// A thread that never wakes is covered by request deadlines.
fn scan_for_stalls(ctx: &SupervisorCtx, stall_timeout: Duration) {
    let slots = &ctx.spawner.slots;
    for (wid, slot) in slots.iter().enumerate() {
        let health = Arc::clone(&slot.health.lock().unwrap());
        if !health.stalled(stall_timeout) {
            continue;
        }
        crate::log_warn!(
            "supervisor",
            "worker {wid} stalled (busy, no heartbeat for >{stall_timeout:?}); respawning"
        );
        let next_gen = slot.mailbox.bump_generation();
        {
            let m = ctx.spawner.metrics.lock().unwrap();
            m.stalls.inc();
            m.respawns.inc();
        }
        let handle = ctx.spawner.spawn(wid, next_gen);
        ctx.worker_handles.lock().unwrap().push(handle);
        if ctx.shutting_down.load(Ordering::SeqCst) {
            slot.mailbox.push_control(ControlMsg::Shutdown);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    submit_rx: Receiver<InferenceRequest>,
    mailboxes: Vec<Arc<WorkerBox>>,
    batcher_cfg: BatcherConfig,
    routing: RoutingKind,
    done_rx: Receiver<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    responder: Responder,
    collector: Arc<TraceCollector>,
) {
    let mut batcher = DynamicBatcher::new(batcher_cfg);
    let mut policy = routing.build();
    // pre-cloned gauge handle: the depth update must not take the
    // metrics mutex once per loop iteration
    let queue_depth = Arc::clone(&metrics.lock().unwrap().queue_depth);
    let mut open = true;
    while open || batcher.pending() > 0 {
        if open {
            match submit_rx.recv_timeout(batcher_cfg.max_wait.max(Duration::from_micros(100))) {
                Ok(req) => batcher.push(req),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // completion feedback for load-aware policies
        while let Ok(wid) = done_rx.try_recv() {
            policy.on_complete(wid);
        }
        // requests whose deadline passed while queued: typed fail now,
        // before they waste a batch slot
        for req in batcher.expire(Instant::now()) {
            fail_expired_request(req, &responder, &metrics, &collector);
        }
        let force = !open;
        while let Some(batch) = batcher.pop_ready(Instant::now(), force) {
            metrics.lock().unwrap().record_batch(batch.input.len());
            let wid = policy.pick(mailboxes.len());
            policy.on_dispatch(wid);
            mailboxes[wid].push_batch(batch);
        }
        queue_depth.set(batcher.pending() as i64);
    }
    queue_depth.set(0);
    // queued batches now live in worker mailboxes; the coordinator's
    // shutdown (or teardown) ends the workers through the control plane
}

/// Fail one request whose deadline expired in the dispatcher queue.
fn fail_expired_request(
    req: InferenceRequest,
    responder: &Responder,
    metrics: &Arc<Mutex<ServingMetrics>>,
    collector: &TraceCollector,
) {
    let latency = req.submitted_at.elapsed();
    {
        let mut m = metrics.lock().unwrap();
        m.record_response(req.num_samples(), latency, latency, false);
        m.deadline_exceeded.inc();
    }
    // deadline failures are always trace-worthy: force-complete a tree
    // (merging gateway-recorded spans when the request was sampled) whose
    // only server span is the queue time that ate the budget
    if collector.enabled() {
        let start_us = trace::us_since_epoch(req.submitted_at);
        let end_us = trace::now_us();
        let queue = Span::new(
            trace::SPAN_QUEUE,
            trace::BATCHER_TID,
            start_us,
            end_us.saturating_sub(start_us),
        );
        collector.force(req.trace, &req.model, start_us, end_us, vec![queue]);
    }
    responder.deliver(InferenceResponse {
        id: req.id,
        result: Err(ServeError::new(
            ServeErrorKind::DeadlineExceeded,
            format!("deadline passed after {latency:?} in queue"),
        )),
        queue_time: latency,
        latency,
        worker: usize::MAX,
        faults_detected: 0,
    });
}

/// Construct the configured backend with a private plan store (the CLI /
/// examples path — a single core gains nothing from sharing).  Engines
/// wrapping PJRT state are not `Send`; call this from the thread that
/// will use the backend.
pub fn build_backend(cfg: &CoordinatorConfig, wid: usize) -> Result<Box<dyn GemmBackend>, String> {
    build_backend_with_store(cfg, wid, Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity)))
}

/// `build_backend_with_runtime` without a fabric: the native engine owns
/// a private pool (standalone cores, sweeps).
pub fn build_backend_with_store(
    cfg: &CoordinatorConfig,
    wid: usize,
    store: Arc<PlanStore>,
) -> Result<Box<dyn GemmBackend>, String> {
    build_backend_with_runtime(cfg, wid, store, None)
}

/// Construct the configured backend over the coordinator's shared
/// runtime state: the plan store (every worker's core borrows plans from
/// one store) and, for native RNS cores, the execution fabric (every
/// worker's engine fans out on one shared pool under its budget).
pub fn build_backend_with_runtime(
    cfg: &CoordinatorConfig,
    wid: usize,
    store: Arc<PlanStore>,
    fabric: Option<FabricHandle>,
) -> Result<Box<dyn GemmBackend>, String> {
    let seed = cfg.seed ^ (wid as u64).wrapping_mul(0x9E37_79B9);
    match &cfg.backend {
        BackendKind::Fp32 => Ok(Box::new(Fp32Backend)),
        BackendKind::FixedPoint { bits } => {
            Ok(Box::new(FixedPointCore::new(*bits, cfg.h, NoiseModel::None, seed)))
        }
        BackendKind::Rns { bits, redundant, attempts, noise } => {
            let engine: Box<dyn ModularGemmEngine> = match fabric {
                Some(handle) => Box::new(NativeEngine::with_fabric(handle)),
                None => Box::new(NativeEngine::default()),
            };
            let core = RnsCore::with_engine_and_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed)
                    .with_sparse_capture(cfg.sparse_capture),
                engine,
                store,
            )?;
            Ok(Box::new(core))
        }
        BackendKind::RnsPjrt { bits, redundant, attempts, noise } => {
            let rt = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
            let engine = PjrtEngine::load(&rt, &cfg.artifacts_dir, *bits).map_err(|e| e.to_string())?;
            let core = RnsCore::with_engine_and_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed)
                    .with_sparse_capture(cfg.sparse_capture),
                Box::new(engine),
                store,
            )?;
            Ok(Box::new(core))
        }
    }
}

fn split_logits(all: &MatF, offset: usize, n: usize) -> MatF {
    all.slice_rows(offset, offset + n)
}

/// Read-only state every worker shares (one clone per worker thread).
struct WorkerShared {
    cfg: CoordinatorConfig,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    responder: Responder,
    done_tx: Sender<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    fabric: Option<FabricHandle>,
    sup_tx: Sender<SupervisorMsg>,
    mailbox: Arc<WorkerBox>,
    chaos: Arc<Mutex<WorkerChaos>>,
    health: Arc<WorkerHealth>,
    collector: Arc<TraceCollector>,
}

/// Per-worker cumulative-counter snapshots, so each batch reports deltas
/// into the shared metrics (multi-worker totals sum instead of
/// last-writer-wins).  A crashed worker's unflushed partials die with
/// its thread — the redispatched replay flushes exactly once.
#[derive(Default)]
struct WorkerCounters {
    faults: u64,
    corrected: u64,
    plans: u64,
    fast: u64,
    voted: u64,
    exhausted: u64,
    dac: u64,
    adc: u64,
    skipped_dac: u64,
    skipped_adc: u64,
    /// Cumulative per-stage wall-clock snapshot (same delta discipline).
    stage: StageMicros,
}

/// Extract a printable message from a caught panic payload.
fn panic_text(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked (non-string payload)".to_string()
    }
}

fn worker_loop(wid: usize, gen: u64, sh: WorkerShared) {
    // Backend is constructed in-thread (PJRT state is !Send), but borrows
    // the shared plan store + fabric; models come as shared Arcs from the
    // registry.
    let mut backend =
        match build_backend_with_runtime(&sh.cfg, wid, Arc::clone(&sh.store), sh.fabric.clone()) {
            Ok(b) => {
                crate::log_debug!("worker", "worker {wid} ready with backend {}", b.name());
                b
            }
            Err(e) => {
                crate::log_error!("worker", "worker {wid} backend construction failed: {e}");
                // no backend: fail every batch with the construction
                // error, but keep serving the control plane so
                // unload_model never hangs on a dead worker
                loop {
                    sh.health.beat();
                    match sh.mailbox.recv(gen) {
                        Mail::Superseded => return,
                        Mail::Control(ControlMsg::Shutdown) => break,
                        Mail::Control(ControlMsg::Unload { ack, .. }) => {
                            ack.send(UnloadAck { dropped: false }).ok();
                        }
                        Mail::Batch(batch) => fail_batch(
                            wid,
                            &batch,
                            ServeError::internal(&e),
                            &sh.responder,
                            &sh.metrics,
                            &sh.collector,
                        ),
                    }
                }
                while let Some(batch) = sh.mailbox.try_pop_batch(gen) {
                    fail_batch(
                        wid,
                        &batch,
                        ServeError::internal(&e),
                        &sh.responder,
                        &sh.metrics,
                        &sh.collector,
                    );
                }
                return;
            }
        };
    let mut models: HashMap<String, Arc<dyn Model>> = HashMap::new();
    let mut counters = WorkerCounters::default();
    loop {
        sh.health.beat();
        match sh.mailbox.recv(gen) {
            Mail::Superseded => return, // a replacement owns the slot now
            Mail::Control(ControlMsg::Shutdown) => break,
            Mail::Control(ControlMsg::Unload { model, ack }) => {
                // proactive release: drop the shared-instance clone now
                // (the registry and store were already unloaded by the
                // coordinator), and let the backend forget its per-model
                // state — no request for the name is needed anymore
                let dropped = models.remove(&model).is_some();
                backend.release_model(&model);
                crate::log_debug!(
                    "worker",
                    "worker {wid}: control unload `{model}` (held instance: {dropped})"
                );
                ack.send(UnloadAck { dropped }).ok();
            }
            Mail::Batch(batch) => {
                if !serve_guarded(wid, gen, &sh, backend.as_mut(), &mut models, &mut counters, batch)
                {
                    return; // panicked: supervisor notified, thread is done
                }
            }
        }
    }
    // a shutdown must not drop batches the dispatcher already handed us
    while let Some(batch) = sh.mailbox.try_pop_batch(gen) {
        if !serve_guarded(wid, gen, &sh, backend.as_mut(), &mut models, &mut counters, batch) {
            return;
        }
    }
}

/// Serve one batch behind the panic boundary, with chaos injection and
/// heartbeat accounting.  Returns `false` when the thread must exit
/// because the batch path panicked (the supervisor has the batch).
fn serve_guarded(
    wid: usize,
    gen: u64,
    sh: &WorkerShared,
    backend: &mut dyn GemmBackend,
    models: &mut HashMap<String, Arc<dyn Model>>,
    counters: &mut WorkerCounters,
    batch: FormedBatch,
) -> bool {
    // take the injected action out under the slot lock, act after: a
    // chaos stall must not hold the lock the replacement will need
    let action = sh.chaos.lock().unwrap().before_batch(&batch.model);
    sh.health.set_busy(true);
    let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
        match action {
            Some(ChaosAction::Panic) => {
                panic!("chaos: injected panic (worker {wid}, model `{}`)", batch.model)
            }
            Some(ChaosAction::Stall(d)) => std::thread::sleep(d),
            None => {}
        }
        serve_batch(wid, sh, backend, models, counters, &batch);
    }));
    sh.health.set_busy(false);
    match outcome {
        Ok(()) => true,
        Err(payload) => {
            let error = panic_text(payload.as_ref());
            crate::log_error!("worker", "worker {wid} died serving `{}`: {error}", batch.model);
            sh.sup_tx
                .send(SupervisorMsg::WorkerDown { wid, gen, batch: Some(batch), error })
                .ok();
            false
        }
    }
}

fn serve_batch(
    wid: usize,
    sh: &WorkerShared,
    backend: &mut dyn GemmBackend,
    models: &mut HashMap<String, Arc<dyn Model>>,
    counters: &mut WorkerCounters,
    batch: &FormedBatch,
) {
    let picked_up = Instant::now();
    // every member already past its deadline: skip the forward entirely
    if batch.members.iter().all(|(req, _)| req.expired(picked_up)) {
        fail_batch(
            wid,
            batch,
            ServeError::new(ServeErrorKind::DeadlineExceeded, "deadline passed before pickup"),
            &sh.responder,
            &sh.metrics,
            &sh.collector,
        );
        return;
    }
    // tag plan lookups with the model for per-model store counters
    // (and so served plans are pinned until model unload)
    backend.set_model_tag(&batch.model);
    // fetch the shared instance through the registry every batch (one
    // mutex lock — trivial against a forward pass): this is what lets
    // `Coordinator::unload_model` take effect mid-session.  A model
    // unloaded and requested again reloads fresh, and the pointer
    // comparison below detects the new instance and re-warms it.
    let model = match sh.registry.get_or_load(&batch.model) {
        Ok(m) => m,
        Err(e) => {
            crate::log_warn!("worker", "worker {wid}: model `{}` failed to load: {e}", batch.model);
            fail_batch(wid, batch, ServeError::model(e), &sh.responder, &sh.metrics, &sh.collector);
            return;
        }
    };
    let warmed = models.get(&batch.model).is_some_and(|prev| Arc::ptr_eq(prev, &model));
    if !warmed {
        // a fresh instance ends any draining state from a prior unload,
        // so this generation's plans pin again (stale rebuilds from
        // batches that raced the unload stay LRU-bounded instead of
        // leaking as pinned entries)
        sh.store.activate_model(&batch.model);
        // warm the per-layer RNS plans: the shared store deduplicates,
        // so W workers warming the same model build each plan exactly
        // once — the other W-1 warms are store hits that only adopt
        // (and charge their core's one-time weight-DAC energy).  A
        // respawned worker re-warms through the same path: store hits,
        // no rebuilds.
        model.warm(backend);
        crate::log_debug!(
            "worker",
            "worker {wid}: warmed `{}` ({} layer plans adopted)",
            batch.model,
            backend.plans_built()
        );
        // replacing a stale entry also drops this worker's Arc to an
        // unloaded instance, releasing its share of the old weights
        models.insert(batch.model.clone(), Arc::clone(&model));
    }
    let logits = model.forward(&batch.input, backend);
    // fault counters from the RRNS core, per batch
    let (detected, corrected, fast_path, voted, exhausted) = backend_fault_counts(backend);
    let batch_faults = detected.saturating_sub(counters.faults);
    counters.faults = detected;
    // all per-worker cumulative counters accumulate into the shared
    // metrics as deltas (like plans_built) so multi-worker totals sum
    // across workers instead of last-writer-wins
    let corrected_delta = corrected.saturating_sub(counters.corrected);
    counters.corrected = corrected;
    let fast_delta = fast_path.saturating_sub(counters.fast);
    counters.fast = fast_path;
    let voted_delta = voted.saturating_sub(counters.voted);
    counters.voted = voted;
    let exhausted_delta = exhausted.saturating_sub(counters.exhausted);
    counters.exhausted = exhausted;
    // per-stage wall-clock deltas from the backend's cumulative timers
    // (only backends that time their pipeline report them)
    let stage_now = backend.stage_micros();
    let stage_delta = stage_now.map(|now| {
        let d = now.delta_since(&counters.stage);
        counters.stage = now;
        d
    });
    // plans adopted since the last batch: warm-time adoptions land in
    // the first delta, and a steady-state delta > 0 means a layer was
    // first seen mid-request (a warm() gap worth fixing)
    let plans_now = backend.plans_built();
    let plans_delta = plans_now.saturating_sub(counters.plans);
    counters.plans = plans_now;
    // data-converter activity, same delta discipline (deterministic
    // integer counts, so a served stream is exactly comparable to the
    // in-process path — the gateway bit-identity test relies on it)
    let (dac_now, adc_now, skipped_dac_now, skipped_adc_now) = backend
        .meter()
        .map(|m| (m.dac_conversions, m.adc_conversions, m.skipped_dac, m.skipped_adc))
        .unwrap_or((0, 0, 0, 0));
    let dac_delta = dac_now.saturating_sub(counters.dac);
    counters.dac = dac_now;
    let adc_delta = adc_now.saturating_sub(counters.adc);
    counters.adc = adc_now;
    let skipped_dac_delta = skipped_dac_now.saturating_sub(counters.skipped_dac);
    counters.skipped_dac = skipped_dac_now;
    let skipped_adc_delta = skipped_adc_now.saturating_sub(counters.skipped_adc);
    counters.skipped_adc = skipped_adc_now;
    {
        let mut m = sh.metrics.lock().unwrap();
        m.faults_detected.add(batch_faults);
        m.faults_corrected.add(corrected_delta);
        m.decode_fast_path.add(fast_delta);
        m.decode_voted.add(voted_delta);
        m.decode_exhausted.add(exhausted_delta);
        m.plans_built.add(plans_delta);
        m.energy_dac_conversions.add(dac_delta);
        m.energy_adc_conversions.add(adc_delta);
        m.energy_skipped_dac.add(skipped_dac_delta);
        m.energy_skipped_adc.add(skipped_adc_delta);
        // the same deltas, attributed to the model this batch ran — a
        // worker serves one batch (= one model) at a time, so the
        // counter deltas since the previous batch belong to it
        m.record_model_batch(
            &batch.model,
            batch_faults,
            corrected_delta,
            fast_delta,
            voted_delta,
            plans_delta,
        );
    }
    let batch_form_us = picked_up.duration_since(batch.formed_at).as_micros() as u64;
    // per-member (id, samples, queue µs, total µs) for stage histograms
    // and traces — recorded after delivery in one metrics lock
    let mut member_meta: Vec<(RequestId, usize, u64, u64)> =
        Vec::with_capacity(batch.members.len());
    let deliver_start = Instant::now();
    // span-trace attribution, recorded *before* delivery so a reply
    // flushed (and completed) by the gateway loop mid-fan-out can never
    // outrun its own spans.  Stage durations are the exact u64 values
    // the stage histograms observe below, laid out sequentially from
    // pickup (their sum cannot exceed the forward wall time, so the
    // stage spans nest inside the batch span by construction); members
    // that expired during the forward are force-completed here because
    // no reply flush will ever complete them.
    let mut traced: Vec<u64> = Vec::new();
    if sh.collector.enabled() {
        let formed_us = trace::us_since_epoch(batch.formed_at);
        let picked_up_us = trace::us_since_epoch(picked_up);
        let forward_end_us = trace::us_since_epoch(deliver_start);
        let d = stage_delta.unwrap_or_default();
        let wtid = trace::WORKER_TID_BASE + wid as u32;
        let nmembers = batch.members.len() as u64;
        let mut buf = SpanBuffer::new();
        for (i, (req, _)) in batch.members.iter().enumerate() {
            let expired = req.expired(deliver_start);
            if req.trace == 0 && !expired {
                continue;
            }
            let queue_us = batch.formed_at.duration_since(req.submitted_at).as_micros() as u64;
            let tags = [("batch", nmembers), ("member", i as u64)];
            let mut spans = vec![
                Span::new(
                    trace::SPAN_QUEUE,
                    trace::BATCHER_TID,
                    formed_us.saturating_sub(queue_us),
                    queue_us,
                ),
                Span::new(trace::SPAN_BATCH_FORM, trace::BATCHER_TID, formed_us, batch_form_us),
                Span::new(
                    trace::SPAN_BATCH,
                    wtid,
                    picked_up_us,
                    forward_end_us.saturating_sub(picked_up_us),
                )
                .with_args(&tags),
            ];
            if stage_delta.is_some() {
                let mut at = picked_up_us;
                for (name, dur) in [
                    (trace::SPAN_DAC_FORWARD, d.dac_forward_us),
                    (trace::SPAN_ANALOG_GEMM, d.analog_gemm_us),
                    (trace::SPAN_ADC_CAPTURE, d.adc_capture_us),
                    (trace::SPAN_DECODE, d.decode_us),
                ] {
                    spans.push(Span::new(name, wtid, at, dur).with_args(&tags));
                    at = at.saturating_add(dur);
                }
            }
            if expired {
                sh.collector.force(
                    req.trace,
                    &batch.model,
                    formed_us.saturating_sub(queue_us),
                    forward_end_us,
                    spans,
                );
            } else {
                traced.push(req.trace);
                for s in spans {
                    buf.push(req.trace, s);
                }
            }
        }
        buf.flush(&sh.collector);
    }
    for (req, offset) in &batch.members {
        let n = req.num_samples();
        let latency = req.submitted_at.elapsed();
        let queue_time = picked_up.duration_since(req.submitted_at);
        // a member whose deadline passed during the forward gets the
        // typed error — its client stopped waiting at the deadline
        let expired = req.expired(Instant::now());
        {
            let mut m = sh.metrics.lock().unwrap();
            m.record_response(n, latency, queue_time, !expired);
            if expired {
                m.deadline_exceeded.inc();
            }
        }
        let result = if expired {
            Err(ServeError::new(
                ServeErrorKind::DeadlineExceeded,
                format!("completed after the deadline ({latency:?} end-to-end)"),
            ))
        } else {
            Ok(split_logits(&logits, *offset, n))
        };
        let queue_us = batch.formed_at.duration_since(req.submitted_at).as_micros() as u64;
        member_meta.push((req.id, n, queue_us, latency.as_micros() as u64));
        sh.responder.deliver(InferenceResponse {
            id: req.id,
            result,
            queue_time,
            latency,
            worker: wid,
            faults_detected: batch_faults,
        });
    }
    let delivery_us = deliver_start.elapsed().as_micros() as u64;
    // the fan-out span arrives after the fact by necessity; a trace whose
    // reply already flushed (and completed) drops it silently, which is
    // the accepted race — every compute span was recorded pre-delivery
    if !traced.is_empty() {
        let deliver_start_us = trace::us_since_epoch(deliver_start);
        let wtid = trace::WORKER_TID_BASE + wid as u32;
        sh.collector.record_batch(traced.iter().map(|&id| {
            (id, Span::new(trace::SPAN_DELIVERY, wtid, deliver_start_us, delivery_us))
        }));
    }
    {
        let mut m = sh.metrics.lock().unwrap();
        m.stage.batch_form.observe(batch_form_us);
        m.stage.delivery.observe(delivery_us);
        // compute stages only when the backend actually times them —
        // zero-filled observations would poison the histograms for
        // FP32/fixed-point runs
        if let Some(d) = stage_delta {
            m.stage.dac_forward.observe(d.dac_forward_us);
            m.stage.analog_gemm.observe(d.analog_gemm_us);
            m.stage.adc_capture.observe(d.adc_capture_us);
            m.stage.decode.observe(d.decode_us);
        }
        let d = stage_delta.unwrap_or_default();
        for (id, n, queue_us, total_us) in member_meta {
            m.stage.queue.observe(queue_us);
            m.record_trace(RequestTrace {
                id,
                model: batch.model.clone(),
                samples: n,
                worker: wid,
                total_us,
                queue_us,
                batch_form_us,
                dac_us: d.dac_forward_us,
                gemm_us: d.analog_gemm_us,
                adc_us: d.adc_capture_us,
                decode_us: d.decode_us,
                delivery_us,
            });
        }
    }
    sh.done_tx.send(wid).ok();
}

fn backend_fault_counts(backend: &dyn GemmBackend) -> (u64, u64, u64, u64, u64) {
    backend
        .fault_stats()
        .map(|s| (s.detections, s.corrected, s.fast_path_elems, s.voted_elems, s.exhausted))
        .unwrap_or((0, 0, 0, 0, 0))
}

fn fail_batch(
    wid: usize,
    batch: &FormedBatch,
    err: ServeError,
    responder: &Responder,
    metrics: &Arc<Mutex<ServingMetrics>>,
    collector: &TraceCollector,
) {
    let force_trace =
        matches!(err.kind, ServeErrorKind::DeadlineExceeded | ServeErrorKind::Poisoned);
    for (req, _) in &batch.members {
        let latency = req.submitted_at.elapsed();
        {
            let mut m = metrics.lock().unwrap();
            m.record_response(req.num_samples(), latency, latency, false);
            if err.kind == ServeErrorKind::DeadlineExceeded {
                m.deadline_exceeded.inc();
            }
        }
        // deadline/poison failures force a span tree even when unsampled
        // (the gateway completes sampled traces for other error kinds)
        if force_trace && collector.enabled() {
            let start_us = trace::us_since_epoch(req.submitted_at);
            let end_us = trace::now_us();
            let queue = Span::new(
                trace::SPAN_QUEUE,
                trace::BATCHER_TID,
                start_us,
                trace::us_since_epoch(batch.formed_at).saturating_sub(start_us),
            );
            collector.force(req.trace, &batch.model, start_us, end_us, vec![queue]);
        }
        responder.deliver(InferenceResponse {
            id: req.id,
            result: Err(err.clone()),
            queue_time: latency,
            latency,
            worker: wid,
            faults_detected: 0,
        });
    }
}

/// Convenience: build an image batch from raw NHWC data.
pub fn image_batch(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Batch {
    Batch::Images(Nhwc::from_vec(n, h, w, c, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
    }

    /// The built-in synthetic model: servable without artifacts.
    const SYN: &str = "synthetic-mlp";

    fn syn_input(n: usize) -> Batch {
        Batch::Images(Nhwc::zeros(n, 28, 28, 1))
    }

    #[test]
    fn serve_fp32_roundtrip() {
        if !have_artifacts() {
            return; // artifacts not built in this environment
        }
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        let coord = Coordinator::start(cfg);
        let mut ids = Vec::new();
        for _ in 0..5 {
            ids.push(coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1))));
        }
        let resps = coord.collect(5);
        assert_eq!(resps.len(), 5);
        for r in &resps {
            let logits = r.result.as_ref().expect("ok");
            assert_eq!((logits.rows, logits.cols), (1, 10));
        }
        let report = coord.shutdown();
        assert!(report.contains("requests=5"), "{report}");
    }

    #[test]
    fn workers_share_one_plan_store() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(
            BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
            &artifacts_dir(),
        );
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        for _ in 0..9 {
            coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
        }
        let resps = coord.collect(9);
        assert!(resps.iter().all(|r| r.result.is_ok()));
        let store = coord.plan_store();
        let stats = store.stats();
        // the mlp has 3 weight GEMMs: exactly 3 plans exist store-wide,
        // however many of the 3 workers warmed the model
        assert_eq!(stats.builds, 3, "plans deduplicated across workers");
        assert_eq!(stats.resident_plans, 3);
        let report = coord.shutdown();
        assert!(report.contains("plan store: resident=3"), "{report}");
        assert!(report.contains("plan store model=mlp:"), "{report}");
        assert!(report.contains("model=mlp: batches="), "{report}");
        // native RNS workers share one fabric and its line is reported
        assert!(report.contains("fabric: threads="), "{report}");
    }

    #[test]
    fn unknown_model_fails_gracefully() {
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        let coord = Coordinator::start(cfg);
        coord.submit("nope", Batch::Images(Nhwc::zeros(1, 2, 2, 1)));
        let r = coord.recv_timeout(Duration::from_secs(5)).expect("response");
        let err = r.result.unwrap_err();
        assert_eq!(err.kind, ServeErrorKind::Model, "{err}");
        coord.shutdown();
    }

    #[test]
    fn responses_match_request_ids() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        let ids: Vec<RequestId> =
            (0..9).map(|_| coord.submit("mlp", Batch::Images(Nhwc::zeros(2, 28, 28, 1)))).collect();
        let resps = coord.collect(9);
        let mut got: Vec<RequestId> = resps.iter().map(|r| r.id).collect();
        got.sort();
        assert_eq!(got, ids);
        for r in &resps {
            assert_eq!(r.result.as_ref().unwrap().rows, 2);
        }
        coord.shutdown();
    }

    #[test]
    fn unload_without_workers_holding_the_model_is_clean() {
        // control-plane unload of a never-loaded name: no acks claim a
        // drop, no plans evicted, the coordinator keeps serving
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        let coord = Coordinator::start(cfg);
        assert_eq!(coord.unload_model("mlp"), 0);
        coord.submit("nope", Batch::Images(Nhwc::zeros(1, 2, 2, 1)));
        assert!(coord.recv_timeout(Duration::from_secs(5)).is_some());
        let report = coord.shutdown();
        assert!(report.contains("unloads: proactive=1 worker-releases=0"), "{report}");
    }

    #[test]
    fn crashed_worker_respawns_and_batch_redispatches() {
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        cfg.workers = 2;
        cfg.chaos = ChaosSpec::parse("panic@w0:b1").unwrap();
        let coord = Coordinator::start(cfg);
        // four sequential round-trips: the first batch lands on worker 0
        // (round-robin) and panics; its redispatch must still answer
        for i in 0..4u64 {
            let id = coord.submit(SYN, syn_input(1));
            let r = coord.recv_timeout(Duration::from_secs(10)).expect("response");
            assert_eq!(r.id, id, "request {i}");
            assert!(r.result.is_ok(), "request {i}: {:?}", r.result.as_ref().err());
        }
        let report = coord.shutdown();
        assert!(report.contains("requests=4"), "{report}");
        assert!(report.contains("failures=0"), "{report}");
        assert!(
            report.contains("supervision: respawns=1 stalls=0 redispatched=1 poisoned=0"),
            "{report}"
        );
    }

    #[test]
    fn poison_batch_is_quarantined_not_crash_looped() {
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        cfg.workers = 2;
        cfg.poison_threshold = 2;
        cfg.chaos = ChaosSpec::parse(&format!("poison@{SYN}")).unwrap();
        let coord = Coordinator::start(cfg);
        coord.submit(SYN, syn_input(1));
        let r = coord.recv_timeout(Duration::from_secs(10)).expect("response");
        let err = r.result.unwrap_err();
        assert_eq!(err.kind, ServeErrorKind::Poisoned, "{err}");
        assert!(err.message.contains("quarantined"), "{err}");
        // the coordinator survived and still serves the control plane
        let report = coord.shutdown();
        assert!(
            report.contains("supervision: respawns=2 stalls=0 redispatched=1 poisoned=1"),
            "respawn loop must stop at the quarantine bound: {report}"
        );
    }

    #[test]
    fn stalled_worker_is_superseded_and_zombie_still_delivers() {
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        cfg.workers = 1;
        cfg.stall_timeout = Duration::from_millis(60);
        cfg.chaos = ChaosSpec::parse("stall@w0:b1:400ms").unwrap();
        let coord = Coordinator::start(cfg);
        let id = coord.submit(SYN, syn_input(1));
        // the zombie wakes after 400 ms and delivers exactly once
        let r = coord.recv_timeout(Duration::from_secs(10)).expect("response");
        assert_eq!(r.id, id);
        assert!(r.result.is_ok());
        // the replacement thread owns the slot now and serves new traffic
        let id2 = coord.submit(SYN, syn_input(1));
        let r2 = coord.recv_timeout(Duration::from_secs(10)).expect("response");
        assert_eq!(r2.id, id2);
        assert!(r2.result.is_ok());
        let report = coord.shutdown();
        assert!(report.contains("failures=0"), "{report}");
        assert!(report.contains("stalls=1"), "{report}");
        assert!(report.contains("deadline-exceeded=0"), "{report}");
    }

    #[test]
    fn deadline_exceeded_is_typed_and_counted() {
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        cfg.workers = 1;
        // first batch holds the only worker for 300 ms (stall_timeout
        // stays at its generous default: no respawn, just a slow batch)
        cfg.chaos = ChaosSpec::parse("stall@w0:b1:300ms").unwrap();
        let coord = Coordinator::start(cfg);
        let slow = coord.submit(SYN, syn_input(1));
        std::thread::sleep(Duration::from_millis(30)); // separate the batches
        let doomed = coord.submit_with_deadline(SYN, syn_input(1), Some(Duration::from_millis(20)));
        let mut ok_ids = Vec::new();
        let mut deadline_ids = Vec::new();
        for _ in 0..2 {
            let r = coord.recv_timeout(Duration::from_secs(10)).expect("response");
            match &r.result {
                Ok(_) => ok_ids.push(r.id),
                Err(e) => {
                    assert_eq!(e.kind, ServeErrorKind::DeadlineExceeded, "{e}");
                    deadline_ids.push(r.id);
                }
            }
        }
        assert_eq!(ok_ids, vec![slow]);
        assert_eq!(deadline_ids, vec![doomed]);
        let report = coord.shutdown();
        assert!(report.contains("deadline-exceeded=1"), "{report}");
        assert!(report.contains("failures=1"), "{report}");
    }
}
