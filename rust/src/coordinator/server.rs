//! The serving coordinator: a dispatcher thread (dynamic batcher + round-
//! robin tile scheduler) feeding a pool of worker threads, each owning a
//! simulated analog core over *shared* read-only state: one
//! `ModelRegistry` (every worker clones `Arc<dyn Model>` — weights exist
//! once) and one `PlanStore` (every layer's `RnsPlan` exists once,
//! whichever worker builds it first; `Model::warm` from W workers
//! deduplicates to one build per layer).
//!
//! Engines wrapping PJRT state are not `Send`, so every worker constructs
//! its own backend *inside* its thread — mirroring how a real deployment
//! pins one accelerator context per worker.  The RRNS detect→recompute
//! loop (paper §IV) runs inside the core; its fault counters are merged
//! into the serving metrics — globally and per model — and the plan
//! store's hit/miss/residency counters land in the shutdown report.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analog::{FixedPointCore, Fp32Backend, GemmBackend, NoiseModel, RnsCore, RnsCoreConfig};
use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher, FormedBatch};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::router::RoutingKind;
use crate::coordinator::request::{InferenceRequest, InferenceResponse, RequestId};
use crate::nn::models::{Batch, Model, ModelRegistry};
use crate::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use crate::store::{PlanStore, DEFAULT_UNTAGGED_CAPACITY};
use crate::tensor::{MatF, Nhwc};

/// Which simulated hardware the workers run.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// FP32 reference (no analog hardware).
    Fp32,
    /// Regular fixed-point analog core (b_adc = bits).
    FixedPoint { bits: u32 },
    /// RNS analog core; `redundant > 0` enables the RRNS retry loop.
    Rns { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
    /// RNS core executing through the AOT pallas kernel via PJRT.
    RnsPjrt { bits: u32, redundant: usize, attempts: u32, noise: NoiseModel },
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendKind,
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub artifacts_dir: String,
    /// Analog array height.
    pub h: usize,
    pub seed: u64,
    /// Worker routing policy (round-robin or least-outstanding).
    pub routing: RoutingKind,
    /// LRU bound for *untagged* plans in the shared plan store (served
    /// models' plans are tagged and pinned until unload).
    pub plan_store_capacity: usize,
}

impl CoordinatorConfig {
    pub fn new(backend: BackendKind, artifacts_dir: &str) -> Self {
        CoordinatorConfig {
            backend,
            workers: 2,
            batcher: BatcherConfig::default(),
            artifacts_dir: artifacts_dir.to_string(),
            h: 128,
            seed: 0,
            routing: RoutingKind::default(),
            plan_store_capacity: DEFAULT_UNTAGGED_CAPACITY,
        }
    }
}

enum WorkerMsg {
    Batch(FormedBatch),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    submit_tx: Option<Sender<InferenceRequest>>,
    resp_rx: Receiver<InferenceResponse>,
    next_id: AtomicU64,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Mutex<ServingMetrics>>,
    /// Shared read-only plan store (one `RnsPlan` per layer across all
    /// workers); its counters land in the shutdown report.
    store: Arc<PlanStore>,
    /// Shared load-once model instances (one weight copy across workers).
    registry: Arc<ModelRegistry>,
    started: Instant,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (submit_tx, submit_rx) = mpsc::channel::<InferenceRequest>();
        let (resp_tx, resp_rx) = mpsc::channel::<InferenceResponse>();
        let (done_tx, done_rx) = mpsc::channel::<usize>();
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        // built once at startup, handed to every worker: the store is the
        // cross-worker plan memory, the registry the cross-worker weights
        let store = Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity));
        let registry = Arc::new(ModelRegistry::new(&cfg.artifacts_dir));

        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            worker_txs.push(tx);
            let cfg_w = cfg.clone();
            let resp_tx = resp_tx.clone();
            let done_tx = done_tx.clone();
            let metrics = Arc::clone(&metrics);
            let store = Arc::clone(&store);
            let registry = Arc::clone(&registry);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rns-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(wid, cfg_w, store, registry, rx, resp_tx, done_tx, metrics)
                    })
                    .expect("spawn worker"),
            );
        }

        let batcher_cfg = cfg.batcher;
        let routing = cfg.routing;
        let metrics_d = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("rns-dispatcher".into())
            .spawn(move || {
                dispatcher_loop(submit_rx, worker_txs, batcher_cfg, routing, done_rx, metrics_d)
            })
            .expect("spawn dispatcher");

        Coordinator {
            submit_tx: Some(submit_tx),
            resp_rx,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
            workers,
            metrics,
            store,
            registry,
            started: Instant::now(),
        }
    }

    /// The shared plan store (one `Arc<RnsPlan>` per layer across all
    /// workers).  Exposed for tests and ops tooling.
    pub fn plan_store(&self) -> Arc<PlanStore> {
        Arc::clone(&self.store)
    }

    /// The shared model registry (one weight copy across all workers).
    pub fn model_registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Drop a model's shared weights and evict its plans from the store.
    /// Workers re-validate their cached instance against the registry on
    /// every batch, so the unload takes effect mid-session: a later
    /// request for the name reloads fresh weights and re-warms fresh
    /// plans.  A batch already in flight when the unload lands finishes
    /// against the old instance; the store's draining state demotes any
    /// plans it rebuilds to untagged LRU entries, so they cannot stay
    /// pinned under the unloaded tag (a fresh warm re-activates the name
    /// — see `PlanStore::activate_model`; a racing in-flight batch on
    /// another worker after that re-warm can still pin a stale plan, a
    /// narrow window bounded by one model's plan count and cleared by
    /// the next unload).  A worker that never sees the model again
    /// releases its stale clone at shutdown (proactive release needs a
    /// control message — ROADMAP PR-3 follow-up).  Returns how many
    /// plans were evicted.
    pub fn unload_model(&self, name: &str) -> usize {
        // store first: once the name is draining, a worker that reloads
        // the model cannot have its fresh warm pinned and then evicted by
        // a store unload that lands late (registry-first would open that
        // window, leaving the fresh instance's plans demoted forever —
        // `warmed` stays true so no worker would re-activate the name)
        let evicted = self.store.unload_model(name);
        self.registry.unload(name);
        evicted
    }

    /// Submit a request; returns its id immediately.
    pub fn submit(&self, model: &str, input: Batch) -> RequestId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferenceRequest::new(id, model, input);
        self.submit_tx.as_ref().expect("coordinator running").send(req).expect("dispatcher alive");
        id
    }

    /// Blocking receive of the next completed response.
    pub fn recv(&self) -> Option<InferenceResponse> {
        self.resp_rx.recv().ok()
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Option<InferenceResponse> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Drain exactly `n` responses (in completion order).
    pub fn collect(&self, n: usize) -> Vec<InferenceResponse> {
        (0..n).filter_map(|_| self.recv()).collect()
    }

    /// Stop accepting requests, drain workers, and return the final report
    /// (including the plan store's hit/miss counters, per model).
    pub fn shutdown(mut self) -> String {
        drop(self.submit_tx.take()); // dispatcher sees the channel close
        if let Some(d) = self.dispatcher.take() {
            d.join().ok();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
        let wall = self.started.elapsed();
        let mut m = self.metrics.lock().unwrap();
        m.set_plan_store(self.store.stats(), self.store.model_stats());
        m.report(wall)
    }
}

fn dispatcher_loop(
    submit_rx: Receiver<InferenceRequest>,
    worker_txs: Vec<Sender<WorkerMsg>>,
    batcher_cfg: BatcherConfig,
    routing: RoutingKind,
    done_rx: Receiver<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
) {
    let mut batcher = DynamicBatcher::new(batcher_cfg);
    let mut policy = routing.build();
    let mut open = true;
    while open || batcher.pending() > 0 {
        if open {
            match submit_rx.recv_timeout(batcher_cfg.max_wait.max(Duration::from_micros(100))) {
                Ok(req) => batcher.push(req),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // completion feedback for load-aware policies
        while let Ok(wid) = done_rx.try_recv() {
            policy.on_complete(wid);
        }
        let force = !open;
        while let Some(batch) = batcher.pop_ready(Instant::now(), force) {
            metrics.lock().unwrap().record_batch(batch.input.len());
            let wid = policy.pick(worker_txs.len());
            policy.on_dispatch(wid);
            worker_txs[wid].send(WorkerMsg::Batch(batch)).ok();
        }
    }
    for tx in &worker_txs {
        tx.send(WorkerMsg::Shutdown).ok();
    }
}

/// Construct the configured backend with a private plan store (the CLI /
/// examples path — a single core gains nothing from sharing).  Engines
/// wrapping PJRT state are not `Send`; call this from the thread that
/// will use the backend.
pub fn build_backend(cfg: &CoordinatorConfig, wid: usize) -> Result<Box<dyn GemmBackend>, String> {
    build_backend_with_store(cfg, wid, Arc::new(PlanStore::with_capacity(cfg.plan_store_capacity)))
}

/// Construct the configured backend over a shared plan store (the
/// coordinator worker path: every worker's core borrows from one store,
/// so each layer's plan is built once and shared as an `Arc`).
pub fn build_backend_with_store(
    cfg: &CoordinatorConfig,
    wid: usize,
    store: Arc<PlanStore>,
) -> Result<Box<dyn GemmBackend>, String> {
    let seed = cfg.seed ^ (wid as u64).wrapping_mul(0x9E37_79B9);
    match &cfg.backend {
        BackendKind::Fp32 => Ok(Box::new(Fp32Backend)),
        BackendKind::FixedPoint { bits } => {
            Ok(Box::new(FixedPointCore::new(*bits, cfg.h, NoiseModel::None, seed)))
        }
        BackendKind::Rns { bits, redundant, attempts, noise } => {
            let core = RnsCore::with_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed),
                store,
            )?;
            Ok(Box::new(core))
        }
        BackendKind::RnsPjrt { bits, redundant, attempts, noise } => {
            let rt = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
            let engine = PjrtEngine::load(&rt, &cfg.artifacts_dir, *bits).map_err(|e| e.to_string())?;
            let core = RnsCore::with_engine_and_store(
                RnsCoreConfig::for_bits(*bits, cfg.h)
                    .with_noise(*noise)
                    .with_rrns(*redundant, *attempts)
                    .with_seed(seed),
                Box::new(engine),
                store,
            )?;
            Ok(Box::new(core))
        }
    }
}

fn split_logits(all: &MatF, offset: usize, n: usize) -> MatF {
    all.slice_rows(offset, offset + n)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    cfg: CoordinatorConfig,
    store: Arc<PlanStore>,
    registry: Arc<ModelRegistry>,
    rx: Receiver<WorkerMsg>,
    resp_tx: Sender<InferenceResponse>,
    done_tx: Sender<usize>,
    metrics: Arc<Mutex<ServingMetrics>>,
) {
    // Backend is constructed in-thread (PJRT state is !Send), but borrows
    // the shared plan store; models come as shared Arcs from the registry.
    let mut backend = match build_backend_with_store(&cfg, wid, Arc::clone(&store)) {
        Ok(b) => {
            crate::log_debug!("worker", "worker {wid} ready with backend {}", b.name());
            b
        }
        Err(e) => {
            crate::log_error!("worker", "worker {wid} backend construction failed: {e}");
            // fail every batch with the construction error
            while let Ok(WorkerMsg::Batch(batch)) = rx.recv() {
                fail_batch(wid, batch, &e, &resp_tx, &metrics);
            }
            return;
        }
    };
    let mut models: HashMap<String, Arc<dyn Model>> = HashMap::new();
    let mut faults_before = 0u64;
    let mut corrected_before = 0u64;
    let mut plans_before = 0u64;
    let mut fast_before = 0u64;
    let mut voted_before = 0u64;

    while let Ok(msg) = rx.recv() {
        let batch = match msg {
            WorkerMsg::Batch(b) => b,
            WorkerMsg::Shutdown => break,
        };
        // tag plan lookups with the model for per-model store counters
        // (and so served plans are pinned until model unload)
        backend.set_model_tag(&batch.model);
        // fetch the shared instance through the registry every batch (one
        // mutex lock — trivial against a forward pass): this is what lets
        // `Coordinator::unload_model` take effect mid-session.  A model
        // unloaded and requested again reloads fresh, and the pointer
        // comparison below detects the new instance and re-warms it.
        let model = match registry.get_or_load(&batch.model) {
            Ok(m) => m,
            Err(e) => {
                crate::log_warn!("worker", "worker {wid}: model `{}` failed to load: {e}", batch.model);
                fail_batch(wid, batch, &e, &resp_tx, &metrics);
                continue;
            }
        };
        let warmed = models
            .get(&batch.model)
            .map_or(false, |prev| Arc::ptr_eq(prev, &model));
        if !warmed {
            // a fresh instance ends any draining state from a prior
            // unload, so this generation's plans pin again (stale
            // rebuilds from batches that raced the unload stay
            // LRU-bounded instead of leaking as pinned entries)
            store.activate_model(&batch.model);
            // warm the per-layer RNS plans: the shared store deduplicates,
            // so W workers warming the same model build each plan exactly
            // once — the other W-1 warms are store hits that only adopt
            // (and charge their core's one-time weight-DAC energy)
            model.warm(backend.as_mut());
            crate::log_debug!(
                "worker",
                "worker {wid}: warmed `{}` ({} layer plans adopted)",
                batch.model,
                backend.plans_built()
            );
            // replacing a stale entry also drops this worker's Arc to an
            // unloaded instance, releasing its share of the old weights
            models.insert(batch.model.clone(), Arc::clone(&model));
        }
        let picked_up = Instant::now();
        let logits = model.forward(&batch.input, backend.as_mut());
        // fault counters from the RRNS core, per batch
        let (detected, corrected, fast_path, voted) = backend_fault_counts(backend.as_ref());
        let batch_faults = detected.saturating_sub(faults_before);
        faults_before = detected;
        // all per-worker cumulative counters accumulate into the shared
        // metrics as deltas (like plans_built) so multi-worker totals sum
        // across workers instead of last-writer-wins
        let corrected_delta = corrected.saturating_sub(corrected_before);
        corrected_before = corrected;
        let fast_delta = fast_path.saturating_sub(fast_before);
        fast_before = fast_path;
        let voted_delta = voted.saturating_sub(voted_before);
        voted_before = voted;
        // plans adopted since the last batch: warm-time adoptions land in
        // the first delta, and a steady-state delta > 0 means a layer was
        // first seen mid-request (a warm() gap worth fixing)
        let plans_now = backend.plans_built();
        let plans_delta = plans_now.saturating_sub(plans_before);
        plans_before = plans_now;
        {
            let mut m = metrics.lock().unwrap();
            m.faults_detected += batch_faults;
            m.faults_corrected += corrected_delta;
            m.decode_fast_path += fast_delta;
            m.decode_voted += voted_delta;
            m.plans_built += plans_delta;
            // the same deltas, attributed to the model this batch ran —
            // a worker serves one batch (= one model) at a time, so the
            // counter deltas since the previous batch belong to it
            m.record_model_batch(
                &batch.model,
                batch_faults,
                corrected_delta,
                fast_delta,
                voted_delta,
                plans_delta,
            );
        }
        for (req, offset) in batch.members {
            let n = req.num_samples();
            let latency = req.submitted_at.elapsed();
            let queue_time = picked_up.duration_since(req.submitted_at);
            metrics.lock().unwrap().record_response(n, latency, queue_time, true);
            resp_tx
                .send(InferenceResponse {
                    id: req.id,
                    result: Ok(split_logits(&logits, offset, n)),
                    queue_time,
                    latency,
                    worker: wid,
                    faults_detected: batch_faults,
                })
                .ok();
        }
        done_tx.send(wid).ok();
    }
}

fn backend_fault_counts(backend: &dyn GemmBackend) -> (u64, u64, u64, u64) {
    backend
        .fault_stats()
        .map(|s| (s.detections, s.corrected, s.fast_path_elems, s.voted_elems))
        .unwrap_or((0, 0, 0, 0))
}

fn fail_batch(
    wid: usize,
    batch: FormedBatch,
    err: &str,
    resp_tx: &Sender<InferenceResponse>,
    metrics: &Arc<Mutex<ServingMetrics>>,
) {
    for (req, _) in batch.members {
        let latency = req.submitted_at.elapsed();
        metrics.lock().unwrap().record_response(req.num_samples(), latency, latency, false);
        resp_tx
            .send(InferenceResponse {
                id: req.id,
                result: Err(err.to_string()),
                queue_time: latency,
                latency,
                worker: wid,
                faults_detected: 0,
            })
            .ok();
    }
}

/// Convenience: build an image batch from raw NHWC data.
pub fn image_batch(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Batch {
    Batch::Images(Nhwc::from_vec(n, h, w, c, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
    }

    #[test]
    fn serve_fp32_roundtrip() {
        if !have_artifacts() {
            return; // artifacts not built in this environment
        }
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        let coord = Coordinator::start(cfg);
        let mut ids = Vec::new();
        for _ in 0..5 {
            ids.push(coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1))));
        }
        let resps = coord.collect(5);
        assert_eq!(resps.len(), 5);
        for r in &resps {
            let logits = r.result.as_ref().expect("ok");
            assert_eq!((logits.rows, logits.cols), (1, 10));
        }
        let report = coord.shutdown();
        assert!(report.contains("requests=5"), "{report}");
    }

    #[test]
    fn workers_share_one_plan_store() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(
            BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
            &artifacts_dir(),
        );
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        for _ in 0..9 {
            coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
        }
        let resps = coord.collect(9);
        assert!(resps.iter().all(|r| r.result.is_ok()));
        let store = coord.plan_store();
        let stats = store.stats();
        // the mlp has 3 weight GEMMs: exactly 3 plans exist store-wide,
        // however many of the 3 workers warmed the model
        assert_eq!(stats.builds, 3, "plans deduplicated across workers");
        assert_eq!(stats.resident_plans, 3);
        let report = coord.shutdown();
        assert!(report.contains("plan store: resident=3"), "{report}");
        assert!(report.contains("plan store model=mlp:"), "{report}");
        assert!(report.contains("model=mlp: batches="), "{report}");
    }

    #[test]
    fn unknown_model_fails_gracefully() {
        let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
        let coord = Coordinator::start(cfg);
        coord.submit("nope", Batch::Images(Nhwc::zeros(1, 2, 2, 1)));
        let r = coord.recv_timeout(Duration::from_secs(5)).expect("response");
        assert!(r.result.is_err());
        coord.shutdown();
    }

    #[test]
    fn responses_match_request_ids() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
        cfg.workers = 3;
        let coord = Coordinator::start(cfg);
        let ids: Vec<RequestId> =
            (0..9).map(|_| coord.submit("mlp", Batch::Images(Nhwc::zeros(2, 28, 28, 1)))).collect();
        let resps = coord.collect(9);
        let mut got: Vec<RequestId> = resps.iter().map(|r| r.id).collect();
        got.sort();
        assert_eq!(got, ids);
        for r in &resps {
            assert_eq!(r.result.as_ref().unwrap().rows, 2);
        }
        coord.shutdown();
    }
}
