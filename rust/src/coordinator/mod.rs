//! L3 coordinator: request types, the dynamic batcher, the worker-pool
//! serving loop (dispatcher + per-worker analog core), and serving metrics.
//!
//! The RRNS detect→recompute retry (paper §IV) executes inside each
//! worker's `RnsCore`; the coordinator surfaces its fault counters in the
//! serving report.

pub mod batcher;
pub mod chaos;
pub mod config_file;
pub mod mailbox;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use chaos::{ChaosAction, ChaosEvent, ChaosSpec};
pub use metrics::{GatewayReport, ServingMetrics};
pub use router::{RoutingKind, RoutingPolicy};
pub use request::{InferenceRequest, InferenceResponse, RequestId, ServeError, ServeErrorKind};
pub use server::{BackendKind, Coordinator, CoordinatorConfig, CoordinatorHandle};
