//! Config-file loading for the coordinator (TOML-subset via util::config).
//!
//! Example (`configs/rns_b6.toml`):
//! ```toml
//! [core]
//! backend = "rns"        # fp32 | fixed | rns | rns-pjrt
//! bits = 6
//! h = 128
//! redundant = 0
//! attempts = 1
//! noise_p = 0.0
//! sparse_capture = false # conversion-avoiding sparse execution: skip
//!                        # DAC/ADC/CRT work for zero activations
//!                        # (reported as skipped-dac=/skipped-adc= on
//!                        # the `energy:` metrics line)
//!
//! [serve]
//! workers = 2
//! max_batch = 8
//! max_wait_us = 2000
//! routing = "least-outstanding"   # or "round-robin"
//! plan_store_capacity = 64        # LRU bound for untagged (sweep) plans
//! fabric_threads = 0              # shared-fabric thread budget (0 = auto:
//!                                 # RNS_NATIVE_THREADS, else core count)
//! listen_addr = "127.0.0.1:7070"  # TCP gateway (omit to stay in-process)
//! max_sessions = 64               # gateway admission cap
//! idle_timeout_ms = 30000         # per-session idle timeout
//! loop_threads = 1                # readiness-loop threads for the
//!                                 # event-driven session layer (sessions
//!                                 # cost slab entries, not thread pairs)
//! admin_token = "s3cret"          # shared secret for load/unload/shutdown
//!                                 # (empty/unset = loopback-only fallback;
//!                                 # env RNS_ADMIN_TOKEN overrides)
//! stall_timeout_ms = 30000        # supervisor heartbeat stall threshold
//! poison_threshold = 2            # crashes before a batch is quarantined
//! default_deadline_ms = 0         # server-side request deadline (0 = none)
//! trace_slots = 16                # slowest-request trace ring size
//!                                 # (0 = tracing off)
//! trace_sample = 0.0              # span-trace sampling probability for
//!                                 # requests that don't carry their own
//!                                 # trace id (0 = only client-chosen /
//!                                 # forced traces are recorded)
//! chaos = ""                      # seeded fault injection, e.g.
//!                                 # "panic@w0:b3,drop@s1:f2" (tests/CI only)
//! ```

use std::time::Duration;

use crate::analog::NoiseModel;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::chaos::ChaosSpec;
use crate::coordinator::router::RoutingKind;
use crate::coordinator::server::{BackendKind, CoordinatorConfig};
use crate::net::gateway::GatewayConfig;
use crate::util::config::Config;

/// Build a `CoordinatorConfig` from a parsed config file.
pub fn from_config(cfg: &Config, artifacts_dir: &str) -> Result<CoordinatorConfig, String> {
    let bits = cfg.int_or("core.bits", 6) as u32;
    if !(2..=16).contains(&bits) {
        return Err(format!("core.bits = {bits} out of range"));
    }
    let redundant = cfg.int_or("core.redundant", 0);
    if redundant < 0 {
        return Err("core.redundant must be >= 0".into());
    }
    let attempts = cfg.int_or("core.attempts", 1).max(1) as u32;
    let noise_p = cfg.float_or("core.noise_p", 0.0);
    if !(0.0..=1.0).contains(&noise_p) {
        return Err(format!("core.noise_p = {noise_p} not a probability"));
    }
    let noise = if noise_p > 0.0 {
        NoiseModel::ResidueFlip { p: noise_p }
    } else if cfg.float("core.noise_sigma_lsb").is_some() {
        NoiseModel::Gaussian { sigma_lsb: cfg.float_or("core.noise_sigma_lsb", 0.0) }
    } else {
        NoiseModel::None
    };
    let backend = match cfg.str_or("core.backend", "rns").as_str() {
        "fp32" => BackendKind::Fp32,
        "fixed" => BackendKind::FixedPoint { bits },
        "rns" => BackendKind::Rns { bits, redundant: redundant as usize, attempts, noise },
        "rns-pjrt" => {
            BackendKind::RnsPjrt { bits, redundant: redundant as usize, attempts, noise }
        }
        other => return Err(format!("unknown core.backend `{other}`")),
    };
    let routing = match cfg.str_or("serve.routing", "round-robin").as_str() {
        "round-robin" => RoutingKind::RoundRobin,
        "least-outstanding" => RoutingKind::LeastOutstanding,
        other => return Err(format!("unknown serve.routing `{other}`")),
    };
    let mut out = CoordinatorConfig::new(backend, artifacts_dir);
    out.h = cfg.int_or("core.h", 128) as usize;
    if out.h == 0 {
        return Err("core.h must be positive".into());
    }
    out.workers = cfg.int_or("serve.workers", 2).max(1) as usize;
    out.batcher = BatcherConfig {
        max_batch: cfg.int_or("serve.max_batch", 8).max(1) as usize,
        max_wait: Duration::from_micros(cfg.int_or("serve.max_wait_us", 2000).max(0) as u64),
        ..Default::default()
    };
    out.seed = cfg.int_or("core.seed", 0) as u64;
    out.routing = routing;
    out.sparse_capture = cfg.bool_or("core.sparse_capture", false);
    let cap = cfg.int_or("serve.plan_store_capacity", crate::store::DEFAULT_UNTAGGED_CAPACITY as i64);
    if cap < 1 {
        return Err("serve.plan_store_capacity must be >= 1".into());
    }
    out.plan_store_capacity = cap as usize;
    let fabric_threads = cfg.int_or("serve.fabric_threads", 0);
    if fabric_threads < 0 {
        return Err("serve.fabric_threads must be >= 0 (0 = auto)".into());
    }
    out.fabric_threads = fabric_threads as usize;
    let stall_ms = cfg.int_or("serve.stall_timeout_ms", 30_000);
    if stall_ms < 1 {
        return Err("serve.stall_timeout_ms must be >= 1".into());
    }
    out.stall_timeout = Duration::from_millis(stall_ms as u64);
    let poison = cfg.int_or("serve.poison_threshold", 2);
    if poison < 1 {
        return Err("serve.poison_threshold must be >= 1".into());
    }
    out.poison_threshold = poison as u32;
    let deadline_ms = cfg.int_or("serve.default_deadline_ms", 0);
    if deadline_ms < 0 {
        return Err("serve.default_deadline_ms must be >= 0 (0 = none)".into());
    }
    if deadline_ms > 0 {
        out.default_deadline = Some(Duration::from_millis(deadline_ms as u64));
    }
    out.chaos = chaos_from_config(cfg)?;
    let trace_slots = cfg.int_or("serve.trace_slots", out.trace_slots as i64);
    if trace_slots < 0 {
        return Err("serve.trace_slots must be >= 0 (0 = tracing off)".into());
    }
    out.trace_slots = trace_slots as usize;
    let trace_sample = cfg.float_or("serve.trace_sample", out.trace_sample);
    if !(0.0..=1.0).contains(&trace_sample) {
        return Err(format!("serve.trace_sample = {trace_sample} not a probability"));
    }
    out.trace_sample = trace_sample;
    Ok(out)
}

/// The `serve.chaos` spec, if any (shared by coordinator + gateway so
/// one string drives worker faults and session drops together).
fn chaos_from_config(cfg: &Config) -> Result<ChaosSpec, String> {
    let spec = cfg.str_or("serve.chaos", "");
    if spec.is_empty() {
        return Ok(ChaosSpec::default());
    }
    ChaosSpec::parse(&spec).map_err(|e| format!("serve.chaos: {e}"))
}

/// Resolve the admin token: env `RNS_ADMIN_TOKEN` wins, then
/// `serve.admin_token`; empty/unset means no token (loopback-only
/// fallback for admin frames).
pub fn admin_token_from_config(cfg: &Config) -> Option<String> {
    let from_env = std::env::var("RNS_ADMIN_TOKEN").unwrap_or_default();
    let token = if from_env.is_empty() { cfg.str_or("serve.admin_token", "") } else { from_env };
    if token.is_empty() {
        None
    } else {
        Some(token)
    }
}

/// Load from a file path.
pub fn from_file(path: &str, artifacts_dir: &str) -> Result<CoordinatorConfig, String> {
    from_config(&Config::from_file(path)?, artifacts_dir)
}

/// Gateway block of a parsed config: `Some` iff `serve.listen_addr` is
/// set (no listen address = the in-process serving path, as before).
pub fn gateway_from_config(cfg: &Config) -> Result<Option<GatewayConfig>, String> {
    let listen_addr = cfg.str_or("serve.listen_addr", "");
    if listen_addr.is_empty() {
        return Ok(None);
    }
    let defaults = GatewayConfig::default();
    let max_sessions = cfg.int_or("serve.max_sessions", defaults.max_sessions as i64);
    if max_sessions < 1 {
        return Err("serve.max_sessions must be >= 1".into());
    }
    let idle_ms = cfg.int_or("serve.idle_timeout_ms", defaults.idle_timeout.as_millis() as i64);
    if idle_ms < 1 {
        return Err("serve.idle_timeout_ms must be >= 1".into());
    }
    let loop_threads = cfg.int_or("serve.loop_threads", defaults.loop_threads as i64);
    if loop_threads < 1 {
        return Err("serve.loop_threads must be >= 1".into());
    }
    Ok(Some(GatewayConfig {
        listen_addr,
        max_sessions: max_sessions as usize,
        idle_timeout: Duration::from_millis(idle_ms as u64),
        loop_threads: loop_threads as usize,
        admin_token: admin_token_from_config(cfg),
        chaos: chaos_from_config(cfg)?,
    }))
}

/// Gateway block from a file path (`None` if the file has no
/// `serve.listen_addr`).
pub fn gateway_from_file(path: &str) -> Result<Option<GatewayConfig>, String> {
    gateway_from_config(&Config::from_file(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[core]
backend = "rns"
bits = 8
h = 128
redundant = 2
attempts = 3
noise_p = 0.01
seed = 7
sparse_capture = true
[serve]
workers = 3
max_batch = 16
max_wait_us = 500
routing = "least-outstanding"
plan_store_capacity = 32
fabric_threads = 6
"#;

    #[test]
    fn parses_full_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let cc = from_config(&cfg, "/tmp/a").unwrap();
        match cc.backend {
            BackendKind::Rns { bits, redundant, attempts, noise } => {
                assert_eq!(bits, 8);
                assert_eq!(redundant, 2);
                assert_eq!(attempts, 3);
                assert_eq!(noise, NoiseModel::ResidueFlip { p: 0.01 });
            }
            other => panic!("wrong backend {other:?}"),
        }
        assert_eq!(cc.workers, 3);
        assert_eq!(cc.batcher.max_batch, 16);
        assert_eq!(cc.batcher.max_wait, Duration::from_micros(500));
        assert_eq!(cc.routing, RoutingKind::LeastOutstanding);
        assert_eq!(cc.seed, 7);
        assert_eq!(cc.plan_store_capacity, 32);
        assert_eq!(cc.fabric_threads, 6);
        assert!(cc.sparse_capture);
    }

    #[test]
    fn defaults_when_empty() {
        let cc = from_config(&Config::parse("").unwrap(), "/tmp/a").unwrap();
        assert!(matches!(cc.backend, BackendKind::Rns { bits: 6, .. }));
        assert_eq!(cc.workers, 2);
        assert_eq!(cc.routing, RoutingKind::RoundRobin);
        assert_eq!(cc.plan_store_capacity, crate::store::DEFAULT_UNTAGGED_CAPACITY);
        assert_eq!(cc.stall_timeout, Duration::from_secs(30));
        assert_eq!(cc.poison_threshold, 2);
        assert!(cc.default_deadline.is_none());
        assert_eq!(cc.trace_slots, crate::coordinator::metrics::DEFAULT_TRACE_SLOTS);
        assert_eq!(cc.trace_sample, 0.0, "span sampling defaults off");
        assert!(cc.chaos.is_empty());
        assert!(!cc.sparse_capture, "sparse capture defaults off");
    }

    #[test]
    fn supervision_block_parses() {
        let cfg = Config::parse(
            "[serve]\nstall_timeout_ms = 250\npoison_threshold = 1\n\
             default_deadline_ms = 40\ntrace_slots = 4\ntrace_sample = 0.25\n\
             chaos = \"panic@w0:b3, stall@w1:b2:50ms\"\n",
        )
        .unwrap();
        let cc = from_config(&cfg, "/tmp/a").unwrap();
        assert_eq!(cc.stall_timeout, Duration::from_millis(250));
        assert_eq!(cc.poison_threshold, 1);
        assert_eq!(cc.default_deadline, Some(Duration::from_millis(40)));
        assert_eq!(cc.trace_slots, 4);
        assert!((cc.trace_sample - 0.25).abs() < 1e-12);
        assert_eq!(cc.chaos.events.len(), 2);
        // a malformed chaos spec is a config error, not a silent no-op
        let bad = Config::parse("[serve]\nchaos = \"panic@nonsense\"\n").unwrap();
        assert!(from_config(&bad, "/tmp/a").is_err());
    }

    #[test]
    fn gaussian_noise_selected_by_sigma() {
        let cfg = Config::parse("[core]\nnoise_sigma_lsb = 0.4\n").unwrap();
        let cc = from_config(&cfg, "/tmp/a").unwrap();
        match cc.backend {
            BackendKind::Rns { noise: NoiseModel::Gaussian { sigma_lsb }, .. } => {
                assert!((sigma_lsb - 0.4).abs() < 1e-12)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_bad_values() {
        for bad in [
            "[core]\nbackend = \"quantum\"",
            "[core]\nbits = 40",
            "[core]\nnoise_p = 1.5",
            "[core]\nh = 0",
            "[serve]\nrouting = \"random\"",
            "[serve]\nplan_store_capacity = 0",
            "[serve]\nfabric_threads = -1",
            "[serve]\nstall_timeout_ms = 0",
            "[serve]\npoison_threshold = 0",
            "[serve]\ndefault_deadline_ms = -5",
            "[serve]\ntrace_slots = -1",
            "[serve]\ntrace_sample = -0.1",
            "[serve]\ntrace_sample = 1.5",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(from_config(&cfg, "/tmp/a").is_err(), "{bad}");
        }
    }

    #[test]
    fn gateway_block_parses_and_defaults() {
        // no listen address: no gateway, whatever else [serve] says
        let cfg = Config::parse("[serve]\nworkers = 2\n").unwrap();
        assert!(gateway_from_config(&cfg).unwrap().is_none());
        // listen address alone: defaults for the rest
        let cfg = Config::parse("[serve]\nlisten_addr = \"127.0.0.1:7070\"\n").unwrap();
        let gw = gateway_from_config(&cfg).unwrap().expect("gateway");
        assert_eq!(gw.listen_addr, "127.0.0.1:7070");
        assert_eq!(gw.max_sessions, GatewayConfig::default().max_sessions);
        assert_eq!(gw.idle_timeout, GatewayConfig::default().idle_timeout);
        assert_eq!(gw.loop_threads, GatewayConfig::default().loop_threads);
        // full block
        let cfg = Config::parse(
            "[serve]\nlisten_addr = \"0.0.0.0:9000\"\nmax_sessions = 8\nidle_timeout_ms = 1500\n\
             loop_threads = 2\n",
        )
        .unwrap();
        let gw = gateway_from_config(&cfg).unwrap().expect("gateway");
        assert_eq!(gw.listen_addr, "0.0.0.0:9000");
        assert_eq!(gw.max_sessions, 8);
        assert_eq!(gw.idle_timeout, Duration::from_millis(1500));
        assert_eq!(gw.loop_threads, 2);
        assert!(gw.admin_token.is_none(), "unset token means loopback-only fallback");
        // admin token + session-drop chaos flow into the gateway block
        let cfg = Config::parse(
            "[serve]\nlisten_addr = \"127.0.0.1:7070\"\nadmin_token = \"s3cret\"\n\
             chaos = \"drop@s1:f2\"\n",
        )
        .unwrap();
        let gw = gateway_from_config(&cfg).unwrap().expect("gateway");
        assert_eq!(gw.admin_token.as_deref(), Some("s3cret"));
        assert_eq!(gw.chaos.session_drop(1), Some(2));
        assert_eq!(gw.chaos.session_drop(0), None);
        // bad values
        for bad in [
            "[serve]\nlisten_addr = \"x\"\nmax_sessions = 0",
            "[serve]\nlisten_addr = \"x\"\nidle_timeout_ms = 0",
            "[serve]\nlisten_addr = \"x\"\nloop_threads = 0",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(gateway_from_config(&cfg).is_err(), "{bad}");
        }
    }
}
