//! Condvar'd worker mailbox: one wait for both batches and control.
//!
//! Before PR 6 each worker owned two mpsc receivers (batches, control)
//! and — std mpsc having no `select` — polled the control channel every
//! 20 ms while blocking on batches.  Unload acks and shutdown paid that
//! polling tax, and a supervisor would have paid it on every respawn.
//! The mailbox replaces both channels with a single mutex + condvar:
//! `recv` sleeps until *either* kind of message arrives, control drains
//! first (unload/shutdown must not queue behind a deep batch backlog),
//! and wakeups are edge-triggered instead of polled.
//!
//! The mailbox is also the supervisor's respawn primitive.  Mailboxes
//! are per-*slot*, not per-thread: the dispatcher and the control plane
//! address slot `w` forever, while the thread consuming slot `w` may be
//! replaced after a crash or stall.  Each consumer thread is stamped
//! with the slot's `generation` at spawn; `bump_generation` (called by
//! the supervisor when it replaces the thread) makes every `recv` from
//! the old thread return `Mail::Superseded`, so a stalled-but-alive
//! zombie finishes its in-flight batch, observes it lost the slot, and
//! exits without touching the queue the replacement now owns.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// What `recv` produced, in delivery-priority order.
#[derive(Debug, PartialEq, Eq)]
pub enum Mail<B, C> {
    /// A control message (always delivered before queued batches).
    Control(C),
    /// The next queued batch.
    Batch(B),
    /// The slot was handed to a newer thread; the caller must exit
    /// without consuming anything further.
    Superseded,
}

struct State<B, C> {
    batches: VecDeque<B>,
    control: VecDeque<C>,
}

/// One worker slot's inbox (see module docs).
pub struct Mailbox<B, C> {
    state: Mutex<State<B, C>>,
    available: Condvar,
    generation: AtomicU64,
}

impl<B, C> Default for Mailbox<B, C> {
    fn default() -> Self {
        Self::new()
    }
}

impl<B, C> Mailbox<B, C> {
    pub fn new() -> Self {
        Mailbox {
            state: Mutex::new(State { batches: VecDeque::new(), control: VecDeque::new() }),
            available: Condvar::new(),
            generation: AtomicU64::new(0),
        }
    }

    /// The generation a freshly spawned consumer should pass to `recv`.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Retire the current consumer: every subsequent `recv`/`try_pop`
    /// from the old generation returns `Superseded`/`None`.  Returns the
    /// new generation to stamp the replacement thread with.
    pub fn bump_generation(&self) -> u64 {
        // take the lock so the store cannot interleave inside another
        // thread's locked check-then-wait (no missed wakeup)
        let _guard = self.state.lock().unwrap();
        let next = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        self.available.notify_all();
        next
    }

    pub fn push_batch(&self, batch: B) {
        self.state.lock().unwrap().batches.push_back(batch);
        self.available.notify_all();
    }

    pub fn push_control(&self, msg: C) {
        self.state.lock().unwrap().control.push_back(msg);
        self.available.notify_all();
    }

    /// Queued batches not yet picked up (dispatcher routing signal).
    pub fn queued_batches(&self) -> usize {
        self.state.lock().unwrap().batches.len()
    }

    /// Block until a message is available for generation `my_gen`.
    /// Control messages outrank batches; a bumped generation outranks
    /// both.
    pub fn recv(&self, my_gen: u64) -> Mail<B, C> {
        let mut st = self.state.lock().unwrap();
        loop {
            if self.generation.load(Ordering::SeqCst) != my_gen {
                return Mail::Superseded;
            }
            if let Some(c) = st.control.pop_front() {
                return Mail::Control(c);
            }
            if let Some(b) = st.batches.pop_front() {
                return Mail::Batch(b);
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Non-blocking batch pop for the post-shutdown drain: hand back the
    /// next queued batch, or `None` when the queue is empty *or* the
    /// caller no longer owns the slot.
    pub fn try_pop_batch(&self, my_gen: u64) -> Option<B> {
        if self.generation.load(Ordering::SeqCst) != my_gen {
            return None;
        }
        self.state.lock().unwrap().batches.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    type TestBox = Mailbox<u32, &'static str>;

    #[test]
    fn control_outranks_batches() {
        let mb = TestBox::new();
        mb.push_batch(1);
        mb.push_batch(2);
        mb.push_control("unload");
        let g = mb.generation();
        assert_eq!(mb.recv(g), Mail::Control("unload"));
        assert_eq!(mb.recv(g), Mail::Batch(1));
        assert_eq!(mb.recv(g), Mail::Batch(2));
        assert_eq!(mb.queued_batches(), 0);
    }

    #[test]
    fn recv_blocks_until_push() {
        let mb = Arc::new(TestBox::new());
        let g = mb.generation();
        let m2 = Arc::clone(&mb);
        let h = std::thread::spawn(move || m2.recv(g));
        std::thread::sleep(Duration::from_millis(20));
        mb.push_batch(7);
        assert_eq!(h.join().unwrap(), Mail::Batch(7));
    }

    #[test]
    fn bump_supersedes_old_generation() {
        let mb = Arc::new(TestBox::new());
        let old = mb.generation();
        // a blocked old-generation consumer wakes up superseded
        let m2 = Arc::clone(&mb);
        let h = std::thread::spawn(move || m2.recv(old));
        std::thread::sleep(Duration::from_millis(20));
        let new = mb.bump_generation();
        assert_eq!(h.join().unwrap(), Mail::Superseded);
        assert_ne!(old, new);
        // queued work is preserved for the replacement
        mb.push_batch(9);
        assert_eq!(mb.try_pop_batch(old), None, "old gen cannot drain");
        assert_eq!(mb.try_pop_batch(new), Some(9));
    }

    #[test]
    fn try_pop_drains_in_order() {
        let mb = TestBox::new();
        mb.push_batch(1);
        mb.push_batch(2);
        let g = mb.generation();
        assert_eq!(mb.try_pop_batch(g), Some(1));
        assert_eq!(mb.try_pop_batch(g), Some(2));
        assert_eq!(mb.try_pop_batch(g), None);
    }
}
