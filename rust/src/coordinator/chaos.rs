//! Deterministic process-level chaos injection for the serving stack.
//!
//! The residue-level `FaultSpec`/`FaultInjector` (rns/inject.rs) makes
//! every *arithmetic* fault regime reproducible; `ChaosSpec` is the same
//! idea one level up, for *process* faults: worker panics, worker stalls,
//! and gateway connection drops.  Where `FaultSpec` draws channel indices
//! from a seeded RNG, chaos events here are **positional** — "the 3rd
//! batch worker 1 picks up", "the 2nd frame of accepted session 0" — which
//! is stronger than seeded randomness for supervision tests: the scenario
//! is readable in the spec string and replays identically regardless of
//! thread scheduling, because each counter is owned by exactly one
//! injection site.
//!
//! Spec grammar (comma-separated events):
//!   * `panic@w{W}:b{N}`        — worker slot W panics on the Nth batch it
//!     picks up (1-based, counted across respawns of that slot);
//!   * `stall@w{W}:b{N}:{MS}ms` — worker slot W sleeps MS milliseconds
//!     mid-batch on its Nth batch (heartbeat goes stale → supervisor
//!     declares a stall if MS exceeds the stall timeout);
//!   * `poison@{model}`         — every batch of `model` panics the worker
//!     serving it: the crash-loop regime the poison quarantine must bound;
//!   * `drop@s{S}:f{N}`         — the gateway severs accepted session S
//!     (0-based admission order) after reading its Nth frame, exercising
//!     client reconnect + retry.
//!
//! Worker-side counters live in one `Arc<Mutex<WorkerChaos>>` per worker
//! *slot*, created at coordinator start and handed to every (re)spawned
//! thread of that slot — so `panic@w0:b3` fires exactly once even though
//! the replacement worker runs the same loop.

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One injected process-fault event (see module docs for the grammar).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChaosEvent {
    /// Worker slot `worker` panics on the `nth` batch it picks up.
    PanicAtBatch { worker: usize, nth: u64 },
    /// Worker slot `worker` sleeps `ms` mid-batch on its `nth` batch.
    StallAtBatch { worker: usize, nth: u64, ms: u64 },
    /// Any worker serving `model` panics on every batch of it.
    PanicOnModel { model: String },
    /// Gateway drops accepted session `session` after `frames` frames.
    DropSession { session: u64, frames: u64 },
}

/// A full chaos scenario: the parsed event list, shared by the
/// coordinator (worker events) and the gateway (session drops).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosSpec {
    pub events: Vec<ChaosEvent>,
}

/// What a worker should do before serving the current batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// Panic (caught at the worker loop boundary; supervisor respawns).
    Panic,
    /// Sleep this long mid-batch (stall; heartbeat goes stale).
    Stall(Duration),
}

impl ChaosSpec {
    /// Parse the spec grammar; `""` is the empty (chaos-free) spec.
    pub fn parse(spec: &str) -> Result<ChaosSpec, String> {
        let mut events = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, rest) = part
                .split_once('@')
                .ok_or_else(|| format!("chaos event `{part}` missing `@`"))?;
            let ev = match kind {
                "panic" => {
                    let (w, b) = parse_wb(rest)?;
                    ChaosEvent::PanicAtBatch { worker: w, nth: b }
                }
                "stall" => {
                    let mut it = rest.split(':');
                    let w = parse_tag(it.next().unwrap_or(""), 'w')? as usize;
                    let b = parse_tag(it.next().unwrap_or(""), 'b')?;
                    let ms = it
                        .next()
                        .and_then(|s| s.strip_suffix("ms"))
                        .and_then(|s| s.parse::<u64>().ok())
                        .ok_or_else(|| format!("stall event `{part}` needs `:NNNms`"))?;
                    if it.next().is_some() {
                        return Err(format!("stall event `{part}` has trailing fields"));
                    }
                    ChaosEvent::StallAtBatch { worker: w, nth: b, ms }
                }
                "poison" => {
                    if rest.is_empty() {
                        return Err("poison event needs a model name".to_string());
                    }
                    ChaosEvent::PanicOnModel { model: rest.to_string() }
                }
                "drop" => {
                    let mut it = rest.split(':');
                    let s = parse_tag(it.next().unwrap_or(""), 's')?;
                    let f = parse_tag(it.next().unwrap_or(""), 'f')?;
                    if it.next().is_some() {
                        return Err(format!("drop event `{part}` has trailing fields"));
                    }
                    ChaosEvent::DropSession { session: s, frames: f }
                }
                other => return Err(format!("unknown chaos event kind `{other}`")),
            };
            if let ChaosEvent::PanicAtBatch { nth, .. }
            | ChaosEvent::StallAtBatch { nth, .. }
            | ChaosEvent::DropSession { frames: nth, .. } = &ev
            {
                if *nth == 0 {
                    return Err(format!("chaos event `{part}`: counts are 1-based"));
                }
            }
            events.push(ev);
        }
        Ok(ChaosSpec { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The per-slot injection state for worker `wid` — one shared handle
    /// per slot, surviving respawns so positional counts never reset.
    pub fn for_worker(&self, wid: usize) -> Arc<Mutex<WorkerChaos>> {
        let mut panic_at = Vec::new();
        let mut stall_at = Vec::new();
        let mut poison_models = Vec::new();
        for ev in &self.events {
            match ev {
                ChaosEvent::PanicAtBatch { worker, nth } if *worker == wid => {
                    panic_at.push(*nth);
                }
                ChaosEvent::StallAtBatch { worker, nth, ms } if *worker == wid => {
                    stall_at.push((*nth, *ms));
                }
                ChaosEvent::PanicOnModel { model } => poison_models.push(model.clone()),
                _ => {}
            }
        }
        Arc::new(Mutex::new(WorkerChaos { panic_at, stall_at, poison_models, batches_seen: 0 }))
    }

    /// After how many frames should accepted session `session` be severed?
    pub fn session_drop(&self, session: u64) -> Option<u64> {
        self.events.iter().find_map(|ev| match ev {
            ChaosEvent::DropSession { session: s, frames } if *s == session => Some(*frames),
            _ => None,
        })
    }
}

fn parse_tag(s: &str, tag: char) -> Result<u64, String> {
    s.strip_prefix(tag)
        .and_then(|v| v.parse::<u64>().ok())
        .ok_or_else(|| format!("expected `{tag}NNN`, got `{s}`"))
}

fn parse_wb(rest: &str) -> Result<(usize, u64), String> {
    let (w, b) = rest
        .split_once(':')
        .ok_or_else(|| format!("expected `wW:bN`, got `{rest}`"))?;
    Ok((parse_tag(w, 'w')? as usize, parse_tag(b, 'b')?))
}

/// One worker slot's chaos state: which of its batches to kill or stall.
/// `before_batch` is called (under the slot's mutex) by whichever thread
/// currently owns the slot, immediately before the forward pass.
#[derive(Debug)]
pub struct WorkerChaos {
    panic_at: Vec<u64>,
    stall_at: Vec<(u64, u64)>,
    poison_models: Vec<String>,
    batches_seen: u64,
}

impl WorkerChaos {
    /// True when no event can ever fire for this slot (skip the lock).
    pub fn is_inert(&self) -> bool {
        self.panic_at.is_empty() && self.stall_at.is_empty() && self.poison_models.is_empty()
    }

    /// Advance the slot's batch counter and report what (if anything) to
    /// inject for this batch.  Panic wins over stall when both match.
    pub fn before_batch(&mut self, model: &str) -> Option<ChaosAction> {
        self.batches_seen += 1;
        if self.poison_models.iter().any(|m| m == model) {
            return Some(ChaosAction::Panic);
        }
        let n = self.batches_seen;
        if self.panic_at.contains(&n) {
            return Some(ChaosAction::Panic);
        }
        if let Some(&(_, ms)) = self.stall_at.iter().find(|(b, _)| *b == n) {
            return Some(ChaosAction::Stall(Duration::from_millis(ms)));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_event_kind() {
        let spec =
            ChaosSpec::parse("panic@w0:b3, stall@w1:b2:150ms,poison@bad-model,drop@s0:f3").unwrap();
        assert_eq!(
            spec.events,
            vec![
                ChaosEvent::PanicAtBatch { worker: 0, nth: 3 },
                ChaosEvent::StallAtBatch { worker: 1, nth: 2, ms: 150 },
                ChaosEvent::PanicOnModel { model: "bad-model".to_string() },
                ChaosEvent::DropSession { session: 0, frames: 3 },
            ]
        );
        assert!(ChaosSpec::parse("").unwrap().is_empty());
        assert!(ChaosSpec::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "panic@w0",          // missing batch
            "panic@0:3",         // missing tags
            "stall@w0:b1",       // missing duration
            "stall@w0:b1:150",   // missing ms suffix
            "panic@w0:b0",       // counts are 1-based
            "drop@s0",           // missing frame count
            "explode@w0:b1",     // unknown kind
            "poison@",           // empty model
            "panic",             // no @
        ] {
            assert!(ChaosSpec::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn worker_counters_are_positional_and_slot_scoped() {
        let spec = ChaosSpec::parse("panic@w0:b2,stall@w1:b1:50ms").unwrap();
        let w0 = spec.for_worker(0);
        let w1 = spec.for_worker(1);
        let w2 = spec.for_worker(2);
        assert!(w2.lock().unwrap().is_inert());
        {
            let mut c = w0.lock().unwrap();
            assert_eq!(c.before_batch("m"), None);
            assert_eq!(c.before_batch("m"), Some(ChaosAction::Panic));
            assert_eq!(c.before_batch("m"), None, "fires exactly once");
        }
        {
            let mut c = w1.lock().unwrap();
            assert_eq!(
                c.before_batch("m"),
                Some(ChaosAction::Stall(Duration::from_millis(50)))
            );
            assert_eq!(c.before_batch("m"), None);
        }
    }

    #[test]
    fn poison_model_fires_on_every_batch_of_that_model() {
        let spec = ChaosSpec::parse("poison@pill").unwrap();
        let w = spec.for_worker(0);
        let mut c = w.lock().unwrap();
        assert_eq!(c.before_batch("healthy"), None);
        assert_eq!(c.before_batch("pill"), Some(ChaosAction::Panic));
        assert_eq!(c.before_batch("pill"), Some(ChaosAction::Panic));
        assert_eq!(c.before_batch("healthy"), None);
    }

    #[test]
    fn session_drop_lookup() {
        let spec = ChaosSpec::parse("drop@s2:f5").unwrap();
        assert_eq!(spec.session_drop(2), Some(5));
        assert_eq!(spec.session_drop(0), None);
    }
}
