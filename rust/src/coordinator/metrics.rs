//! Serving metrics: throughput, latency percentiles, fault counters —
//! globally and per model — plus the shared plan store's hit/miss and
//! residency counters, the execution fabric's utilization, and the
//! control plane's proactive-unload counters in the shutdown report.
//!
//! Since PR 8 the counters themselves live in a typed
//! [`MetricRegistry`](crate::util::metrics::MetricRegistry): every
//! `ServingMetrics` field is an `Arc<Counter>` handle into one shared
//! registry, the human-readable report reads those same atomics, and
//! the gateway's `/metrics?format=prometheus` endpoint renders the same
//! registry as text exposition — one source of truth, so the exposition
//! and every PR-2..PR-7 report-line parser agree exactly.
//!
//! The registry also carries the per-stage pipeline latency histograms
//! (`rns_stage_latency_us{stage=...}`: admission → queue → batch-form →
//! DAC forward → analog GEMM → ADC capture → decode → delivery) and a
//! bounded ring of the slowest request traces (`trace:` report lines,
//! queryable over the wire via the `Traces` frame).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::runtime::fabric::FabricStats;
use crate::store::{ModelPlanStats, StoreStats};
use crate::util::metrics::{Counter, Gauge, Histogram, MetricRegistry, LATENCY_BUCKETS_US};
use crate::util::stats::Reservoir;

/// Latency/queue/batch-size samples kept for percentile estimation.
/// Algorithm-R reservoirs bound the memory of a long-running server (the
/// PR-2 `Percentiles` vectors grew one entry per request forever); 4096
/// samples keep p99 well inside a percent of the exact value.
const RESERVOIR_CAP: usize = 4096;

/// Slowest-request traces kept by default (`serve.trace_slots`).
pub const DEFAULT_TRACE_SLOTS: usize = 16;

/// The per-stage latency histogram family (shared with the gateway,
/// which observes the `admission` stage into the same family).
pub const STAGE_FAMILY: &str = "rns_stage_latency_us";
const STAGE_HELP: &str = "Pipeline stage latency in microseconds";

/// Get-or-register the stage histogram for one pipeline stage.  One
/// function so the gateway (admission) and the workers (everything
/// else) land in the same family with the same buckets.
pub fn stage_histogram(registry: &MetricRegistry, stage: &str) -> Arc<Histogram> {
    registry.histogram_labeled(STAGE_FAMILY, STAGE_HELP, "stage", stage, &LATENCY_BUCKETS_US)
}

/// Decode / fault / plan counters attributed to one model's batches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelServingStats {
    pub batches: u64,
    pub faults_detected: u64,
    pub faults_corrected: u64,
    pub decode_fast_path: u64,
    pub decode_voted: u64,
    /// Plans adopted by workers while serving this model (plateaus at
    /// workers × layers; the plan store's misses count is the
    /// deduplicated build side).
    pub plans_adopted: u64,
}

/// Registry-backed per-model counters (label-bounded: model names).
struct ModelCounters {
    batches: Arc<Counter>,
    faults_detected: Arc<Counter>,
    faults_corrected: Arc<Counter>,
    decode_fast_path: Arc<Counter>,
    decode_voted: Arc<Counter>,
    plans_adopted: Arc<Counter>,
}

impl ModelCounters {
    fn register(registry: &MetricRegistry, model: &str) -> Self {
        let c = |name: &str, help: &str| registry.counter_labeled(name, help, "model", model);
        ModelCounters {
            batches: c("rns_model_batches_total", "Batches served per model"),
            faults_detected: c("rns_model_faults_detected_total", "RRNS detections per model"),
            faults_corrected: c("rns_model_faults_corrected_total", "RRNS corrections per model"),
            decode_fast_path: c(
                "rns_model_decode_fast_path_total",
                "Fast-path decoded elements per model",
            ),
            decode_voted: c("rns_model_decode_voted_total", "Voted decoded elements per model"),
            plans_adopted: c("rns_model_plans_adopted_total", "Plan adoptions per model"),
        }
    }

    fn snapshot(&self) -> ModelServingStats {
        ModelServingStats {
            batches: self.batches.get(),
            faults_detected: self.faults_detected.get(),
            faults_corrected: self.faults_corrected.get(),
            decode_fast_path: self.decode_fast_path.get(),
            decode_voted: self.decode_voted.get(),
            plans_adopted: self.plans_adopted.get(),
        }
    }
}

/// Per-stage latency histograms the workers/dispatcher observe into
/// (the gateway adds the `admission` stage from its side).
pub struct StageHistograms {
    pub queue: Arc<Histogram>,
    pub batch_form: Arc<Histogram>,
    pub dac_forward: Arc<Histogram>,
    pub analog_gemm: Arc<Histogram>,
    pub adc_capture: Arc<Histogram>,
    pub decode: Arc<Histogram>,
    pub delivery: Arc<Histogram>,
}

impl StageHistograms {
    fn register(registry: &MetricRegistry) -> Self {
        StageHistograms {
            queue: stage_histogram(registry, "queue"),
            batch_form: stage_histogram(registry, "batch_form"),
            dac_forward: stage_histogram(registry, "dac_forward"),
            analog_gemm: stage_histogram(registry, "analog_gemm"),
            adc_capture: stage_histogram(registry, "adc_capture"),
            decode: stage_histogram(registry, "decode"),
            delivery: stage_histogram(registry, "delivery"),
        }
    }
}

/// One request's per-stage timing breakdown (microseconds).  Batch-wide
/// stages (form, DAC, GEMM, ADC, decode, delivery) are attributed to
/// every member of the batch — the trace answers "what did this request
/// wait on", and it waited on its whole batch.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RequestTrace {
    pub id: u64,
    pub model: String,
    pub samples: usize,
    pub worker: usize,
    /// Submit → delivery, the request's full latency.
    pub total_us: u64,
    pub queue_us: u64,
    pub batch_form_us: u64,
    pub dac_us: u64,
    pub gemm_us: u64,
    pub adc_us: u64,
    pub decode_us: u64,
    pub delivery_us: u64,
}

impl RequestTrace {
    fn render(&self) -> String {
        format!(
            "trace: id={} model={} samples={} worker={} total={}µs queue={}µs form={}µs \
             dac={}µs gemm={}µs adc={}µs decode={}µs delivery={}µs",
            self.id,
            self.model,
            self.samples,
            self.worker,
            self.total_us,
            self.queue_us,
            self.batch_form_us,
            self.dac_us,
            self.gemm_us,
            self.adc_us,
            self.decode_us,
            self.delivery_us,
        )
    }
}

/// Bounded keep-the-slowest ring: offers replace the current fastest
/// entry once the ring is full, so memory is O(cap) however long the
/// server runs and the retained set is always the slowest-N seen.
pub struct TraceRing {
    cap: usize,
    slots: Vec<RequestTrace>,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing { cap, slots: Vec::with_capacity(cap.min(64)) }
    }

    pub fn offer(&mut self, t: RequestTrace) {
        if self.cap == 0 {
            return;
        }
        if self.slots.len() < self.cap {
            self.slots.push(t);
            return;
        }
        let (idx, fastest) = self
            .slots
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.total_us)
            .map(|(i, s)| (i, s.total_us))
            .expect("non-empty ring");
        if t.total_us > fastest {
            self.slots[idx] = t;
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Slowest-first trace lines, headed by a `slow traces:` summary.
    pub fn render(&self) -> String {
        let mut out = format!("slow traces: kept={} cap={}", self.slots.len(), self.cap);
        let mut sorted: Vec<&RequestTrace> = self.slots.iter().collect();
        sorted.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.id.cmp(&b.id)));
        for t in sorted {
            out.push('\n');
            out.push_str(&t.render());
        }
        out
    }
}

pub struct ServingMetrics {
    /// The typed registry every counter below lives in; the gateway and
    /// the Prometheus endpoint render this same registry.
    registry: Arc<MetricRegistry>,
    pub requests: Arc<Counter>,
    pub samples: Arc<Counter>,
    pub batches: Arc<Counter>,
    pub failures: Arc<Counter>,
    pub faults_detected: Arc<Counter>,
    pub faults_corrected: Arc<Counter>,
    /// RRNS elements decoded by the batched no-fault fast path vs the
    /// per-element voting fallback (two-tier decode; fast/(fast+voted)
    /// near 1.0 is the healthy steady state for clean hardware).
    pub decode_fast_path: Arc<Counter>,
    pub decode_voted: Arc<Counter>,
    /// Elements still undecodable after `max_attempts` (best-effort CRT
    /// fallback) — the live health signal of the analog array.
    pub decode_exhausted: Arc<Counter>,
    /// Per-layer RNS plans adopted across all workers (plateaus at
    /// workers × model layers — adoption is per worker; the shared plan
    /// store's `builds` counter shows the deduplicated build count).
    pub plans_built: Arc<Counter>,
    /// Data-converter activity summed across workers (exact integer
    /// conversion counts from each core's `EnergyMeter` — deterministic,
    /// which is what lets the gateway tests compare a served stream
    /// against the in-process path down to the converter count).
    pub energy_dac_conversions: Arc<Counter>,
    pub energy_adc_conversions: Arc<Counter>,
    /// Conversions sparse capture proved unnecessary and skipped (zero
    /// activations / structurally-zero output rows); always 0 unless the
    /// backend runs with `sparse_capture` on.
    pub energy_skipped_dac: Arc<Counter>,
    pub energy_skipped_adc: Arc<Counter>,
    /// Proactive unloads issued through the worker control plane, and
    /// how many worker-held model instances they released (a worker that
    /// never held the model acks without a release).
    pub unload_requests: Arc<Counter>,
    pub proactive_releases: Arc<Counter>,
    /// Supervision counters (PR 6): worker threads replaced (crash or
    /// stall), stalls among them, crashed in-flight batches replayed on a
    /// healthy slot, batches quarantined at the poison threshold, and
    /// requests failed with the typed `DeadlineExceeded`.
    pub respawns: Arc<Counter>,
    pub stalls: Arc<Counter>,
    pub redispatched: Arc<Counter>,
    pub poisoned: Arc<Counter>,
    pub deadline_exceeded: Arc<Counter>,
    /// Requests currently queued in the dynamic batcher (set by the
    /// dispatcher each loop iteration).
    pub queue_depth: Arc<Gauge>,
    /// End-to-end request latency histogram (submit → delivery).
    pub request_latency: Arc<Histogram>,
    /// Per-stage pipeline latency histograms.
    pub stage: StageHistograms,
    /// Same counters keyed by model (BTreeMap: stable report order).
    per_model: BTreeMap<String, ModelCounters>,
    /// Plan-store snapshot attached at shutdown.
    plan_store: Option<(StoreStats, Vec<ModelPlanStats>)>,
    /// Execution-fabric snapshot attached at shutdown (native RNS
    /// backends only).
    fabric: Option<FabricStats>,
    /// TCP gateway snapshot (sessions/frames/latency), attached by the
    /// gateway before it renders a live or shutdown report.
    gateway: Option<GatewayReport>,
    /// Slowest-N request traces (bounded ring; `trace:` report lines).
    traces: TraceRing,
    latency_us: Reservoir,
    queue_us: Reservoir,
    batch_sizes: Reservoir,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics::with_registry(Arc::new(MetricRegistry::new()))
    }
}

/// The TCP serving gateway's counters, rendered as `gateway:` report
/// lines.  Latency here is gateway-side request latency (submit →
/// response delivery), so it includes queueing + compute but not the
/// client's network hop.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GatewayReport {
    pub sessions_accepted: u64,
    pub sessions_active: u64,
    pub sessions_rejected: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub protocol_errors: u64,
    pub http_scrapes: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
}

impl ServingMetrics {
    /// Build the serving counters inside `registry` (one registry per
    /// coordinator; `Default` makes a private one for tests/standalone).
    pub fn with_registry(registry: Arc<MetricRegistry>) -> Self {
        let c = |name: &str, help: &str| registry.counter(name, help);
        ServingMetrics {
            requests: c("rns_requests_total", "Requests answered (ok + failed)"),
            samples: c("rns_samples_total", "Input samples across all requests"),
            batches: c("rns_batches_total", "Hardware batches formed"),
            failures: c("rns_failures_total", "Requests answered with an error"),
            faults_detected: c("rns_faults_detected_total", "RRNS Case-2 detections"),
            faults_corrected: c("rns_faults_corrected_total", "RRNS majority corrections"),
            decode_fast_path: c(
                "rns_decode_fast_path_total",
                "Elements decoded by the batched no-fault fast path",
            ),
            decode_voted: c(
                "rns_decode_voted_total",
                "Elements decoded by the per-element voting fallback",
            ),
            decode_exhausted: c(
                "rns_decode_exhausted_total",
                "Elements undecodable after max_attempts (best-effort fallback)",
            ),
            plans_built: c("rns_plans_built_total", "Per-layer plan adoptions across workers"),
            energy_dac_conversions: c("rns_dac_conversions_total", "DAC conversions"),
            energy_adc_conversions: c("rns_adc_conversions_total", "ADC conversions"),
            energy_skipped_dac: c(
                "rns_dac_conversions_skipped_total",
                "DAC conversions skipped by sparse capture",
            ),
            energy_skipped_adc: c(
                "rns_adc_conversions_skipped_total",
                "ADC conversions skipped by sparse capture",
            ),
            unload_requests: c("rns_unloads_total", "Proactive control-plane unloads"),
            proactive_releases: c(
                "rns_unload_releases_total",
                "Worker-held model instances released by unloads",
            ),
            respawns: c("rns_supervision_respawns_total", "Worker threads replaced"),
            stalls: c("rns_supervision_stalls_total", "Stalled workers superseded"),
            redispatched: c(
                "rns_supervision_redispatched_total",
                "Crashed in-flight batches replayed on a healthy slot",
            ),
            poisoned: c(
                "rns_supervision_poisoned_total",
                "Batches quarantined at the poison threshold",
            ),
            deadline_exceeded: c(
                "rns_deadline_exceeded_total",
                "Requests failed with DeadlineExceeded",
            ),
            queue_depth: registry.gauge("rns_queue_depth", "Requests queued in the batcher"),
            request_latency: registry.histogram(
                "rns_request_latency_us",
                "End-to-end request latency in microseconds",
                &LATENCY_BUCKETS_US,
            ),
            stage: StageHistograms::register(&registry),
            per_model: BTreeMap::new(),
            plan_store: None,
            fabric: None,
            gateway: None,
            traces: TraceRing::new(DEFAULT_TRACE_SLOTS),
            // fixed seeds: replacement decisions must not depend on how
            // many samples a previous run saw
            latency_us: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A7),
            queue_us: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A8),
            batch_sizes: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A9),
            registry,
        }
    }

    /// The shared registry (the gateway registers its counters here and
    /// the Prometheus endpoint renders it).
    pub fn registry(&self) -> Arc<MetricRegistry> {
        Arc::clone(&self.registry)
    }

    /// Resize the slow-trace ring (`serve.trace_slots`); existing
    /// entries are re-offered so shrinking keeps the slowest.
    pub fn set_trace_capacity(&mut self, cap: usize) {
        let old = std::mem::replace(&mut self.traces, TraceRing::new(cap));
        for t in old.slots {
            self.traces.offer(t);
        }
    }

    pub fn record_batch(&mut self, batch_samples: usize) {
        self.batches.inc();
        self.batch_sizes.add(batch_samples as f64);
    }

    /// Accumulate one served batch's counter deltas under its model.
    #[allow(clippy::too_many_arguments)]
    pub fn record_model_batch(
        &mut self,
        model: &str,
        faults_detected: u64,
        faults_corrected: u64,
        decode_fast_path: u64,
        decode_voted: u64,
        plans_adopted: u64,
    ) {
        let registry = &self.registry;
        let e = self
            .per_model
            .entry(model.to_string())
            .or_insert_with(|| ModelCounters::register(registry, model));
        e.batches.inc();
        e.faults_detected.add(faults_detected);
        e.faults_corrected.add(faults_corrected);
        e.decode_fast_path.add(decode_fast_path);
        e.decode_voted.add(decode_voted);
        e.plans_adopted.add(plans_adopted);
    }

    pub fn model_stats(&self, model: &str) -> Option<ModelServingStats> {
        self.per_model.get(model).map(ModelCounters::snapshot)
    }

    /// Offer one request's stage breakdown to the slowest-N ring.
    pub fn record_trace(&mut self, t: RequestTrace) {
        self.traces.offer(t);
    }

    /// The `slow traces:` block alone (the `Traces` wire frame's reply;
    /// also appended to the full report).
    pub fn traces_report(&self) -> String {
        self.traces.render()
    }

    /// Attach the shared plan store's counters for the shutdown report.
    pub fn set_plan_store(&mut self, stats: StoreStats, per_model: Vec<ModelPlanStats>) {
        self.plan_store = Some((stats, per_model));
    }

    /// Attach the shared execution fabric's shape + utilization counters
    /// for the shutdown report.
    pub fn set_fabric(&mut self, stats: FabricStats) {
        self.fabric = Some(stats);
    }

    /// Attach the TCP gateway's session/frame counters (rendered as
    /// `gateway:` lines after the global + per-model blocks).
    pub fn set_gateway(&mut self, g: GatewayReport) {
        self.gateway = Some(g);
    }

    /// Record one control-plane unload and how many worker-held
    /// instances it released.
    pub fn record_unload(&mut self, released: u64) {
        self.unload_requests.inc();
        self.proactive_releases.add(released);
    }

    pub fn record_response(&mut self, samples: usize, latency: Duration, queue: Duration, ok: bool) {
        self.requests.inc();
        self.samples.add(samples as u64);
        if !ok {
            self.failures.inc();
        }
        let latency_us = latency.as_secs_f64() * 1e6;
        self.latency_us.add(latency_us);
        self.queue_us.add(queue.as_secs_f64() * 1e6);
        self.request_latency.observe(latency.as_micros() as u64);
    }

    pub fn latency_percentile_us(&mut self, q: f64) -> f64 {
        self.latency_us.percentile(q)
    }

    pub fn queue_percentile_us(&mut self, q: f64) -> f64 {
        self.queue_us.percentile(q)
    }

    pub fn mean_batch_size(&mut self) -> f64 {
        if self.batches.get() == 0 { 0.0 } else { self.batch_sizes.percentile(50.0) }
    }

    /// Push the snapshot-sourced blocks (plan store, fabric) into the
    /// registry so the Prometheus exposition carries them too.  Their
    /// monotone counters sync via `raise_to` (snapshots are cumulative);
    /// residency is a gauge.  Called right before rendering exposition.
    pub fn sync_registry(&self) {
        if let Some((stats, _)) = &self.plan_store {
            let r = &self.registry;
            r.gauge("rns_plan_store_resident_plans", "Plans resident in the shared store")
                .set(stats.resident_plans as i64);
            r.gauge("rns_plan_store_resident_bytes", "Bytes resident in the shared store")
                .set(stats.resident_bytes as i64);
            r.counter("rns_plan_store_builds_total", "Deduplicated plan builds")
                .raise_to(stats.builds);
            r.counter("rns_plan_store_hits_total", "Plan store hits").raise_to(stats.hits);
            r.counter("rns_plan_store_evicted_total", "Plans evicted from the untagged LRU")
                .raise_to(stats.evicted);
        }
        if let Some(f) = &self.fabric {
            let r = &self.registry;
            r.gauge("rns_fabric_threads", "Execution fabric total threads")
                .set(f.total_threads as i64);
            r.gauge("rns_fabric_helpers", "Execution fabric helper threads")
                .set(f.helper_threads as i64);
            r.counter("rns_fabric_jobs_total", "Jobs run on the fabric").raise_to(f.jobs);
            r.counter("rns_fabric_tasks_total", "Tasks run on the fabric").raise_to(f.tasks);
        }
    }

    /// Render the registry as Prometheus text exposition
    /// (`text/plain; version=0.0.4`), syncing snapshot blocks first.
    pub fn render_prometheus(&self) -> String {
        self.sync_registry();
        self.registry.render_prometheus()
    }

    /// Render a one-screen report (used by `serve` and the e2e example).
    /// Global lines come first and keep their PR-2 shapes (parsers key on
    /// the first occurrence of `fast-path=` etc.); per-model decode lines
    /// and the plan-store block follow.  Every value is read from the
    /// registry counters — the same atomics the Prometheus exposition
    /// renders, which is what keeps the two in exact agreement.
    pub fn report(&mut self, wall: Duration) -> String {
        let thpt = self.samples.get() as f64 / wall.as_secs_f64().max(1e-9);
        let mb = self.mean_batch_size();
        let (p50, p95, p99) = (
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        );
        let q50 = self.queue_percentile_us(50.0);
        let mut out = format!(
            "requests={} samples={} batches={} failures={}\n\
             throughput={:.1} samples/s  median batch={:.1}\n\
             latency p50={:.0}µs p95={:.0}µs p99={:.0}µs  queue p50={:.0}µs\n\
             layer plans built={}\n\
             faults: detected={} corrected={}\n\
             decode: fast-path={} voted={} exhausted={}",
            self.requests.get(),
            self.samples.get(),
            self.batches.get(),
            self.failures.get(),
            thpt,
            mb,
            p50,
            p95,
            p99,
            q50,
            self.plans_built.get(),
            self.faults_detected.get(),
            self.faults_corrected.get(),
            self.decode_fast_path.get(),
            self.decode_voted.get(),
            self.decode_exhausted.get(),
        );
        // skipped-* appended after the PR-5 keys so parsers keyed on the
        // first dac-/adc-conversions occurrence keep working
        out.push_str(&format!(
            "\nenergy: dac-conversions={} adc-conversions={} skipped-dac={} skipped-adc={}",
            self.energy_dac_conversions.get(),
            self.energy_adc_conversions.get(),
            self.energy_skipped_dac.get(),
            self.energy_skipped_adc.get(),
        ));
        out.push_str(&format!(
            "\nunloads: proactive={} worker-releases={}",
            self.unload_requests.get(),
            self.proactive_releases.get(),
        ));
        out.push_str(&format!(
            "\nsupervision: respawns={} stalls={} redispatched={} poisoned={} \
             deadline-exceeded={}",
            self.respawns.get(),
            self.stalls.get(),
            self.redispatched.get(),
            self.poisoned.get(),
            self.deadline_exceeded.get(),
        ));
        for (model, s) in &self.per_model {
            let s = s.snapshot();
            out.push_str(&format!(
                "\nmodel={model}: batches={} decode fast-path={} voted={} \
                 faults detected={} corrected={} plans adopted={}",
                s.batches,
                s.decode_fast_path,
                s.decode_voted,
                s.faults_detected,
                s.faults_corrected,
                s.plans_adopted,
            ));
        }
        if let Some((stats, per_model)) = &self.plan_store {
            out.push_str(&format!(
                "\nplan store: resident={} bytes={} builds={} hits={} evicted={}",
                stats.resident_plans, stats.resident_bytes, stats.builds, stats.hits, stats.evicted,
            ));
            for m in per_model {
                out.push_str(&format!(
                    "\nplan store model={}: resident={} bytes={} hits={} misses={}",
                    m.model, m.plans, m.bytes, m.hits, m.misses,
                ));
            }
        }
        if let Some(f) = &self.fabric {
            out.push_str(&format!(
                "\nfabric: threads={} helpers={} workers={} budget={} jobs={} tasks={}",
                f.total_threads, f.helper_threads, f.workers, f.budget, f.jobs, f.tasks,
            ));
        }
        if let Some(g) = &self.gateway {
            out.push_str(&format!(
                "\ngateway: sessions={} active={} rejects={} frames-in={} frames-out={} \
                 protocol-errors={} scrapes={}",
                g.sessions_accepted,
                g.sessions_active,
                g.sessions_rejected,
                g.frames_in,
                g.frames_out,
                g.protocol_errors,
                g.http_scrapes,
            ));
            out.push_str(&format!(
                "\ngateway latency: p50={:.0}µs p99={:.0}µs",
                g.latency_p50_us, g.latency_p99_us,
            ));
        }
        if !self.traces.is_empty() {
            out.push('\n');
            out.push_str(&self.traces.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::default();
        m.record_batch(4);
        m.record_response(4, Duration::from_micros(100), Duration::from_micros(10), true);
        m.record_response(2, Duration::from_micros(300), Duration::from_micros(20), false);
        assert_eq!(m.requests.get(), 2);
        assert_eq!(m.samples.get(), 6);
        assert_eq!(m.failures.get(), 1);
        let p50 = m.latency_percentile_us(50.0);
        assert!((p50 - 200.0).abs() < 1.0);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("throughput=6.0"));
        // the supervision line renders even when nothing went wrong
        assert!(
            rep.contains(
                "supervision: respawns=0 stalls=0 redispatched=0 poisoned=0 deadline-exceeded=0"
            ),
            "{rep}"
        );
    }

    #[test]
    fn latency_samples_are_bounded_by_the_reservoir() {
        // a long-running server must not grow a vector per request: the
        // reservoir caps retained samples while percentiles stay sane
        let mut m = ServingMetrics::default();
        for i in 0..100_000u64 {
            m.record_response(1, Duration::from_micros(i), Duration::from_micros(i / 2), true);
        }
        let p50 = m.latency_percentile_us(50.0);
        assert!((20_000.0..=80_000.0).contains(&p50), "p50 ={p50}");
        let p99 = m.latency_percentile_us(99.0);
        assert!(p99 > p50, "p99 {p99} above p50 {p50}");
        assert!(m.queue_percentile_us(50.0) < p50);
    }

    #[test]
    fn per_model_and_plan_store_sections() {
        let mut m = ServingMetrics::default();
        m.record_model_batch("mlp", 2, 1, 100, 4, 3);
        m.record_model_batch("mlp", 0, 0, 50, 0, 0);
        m.record_model_batch("bert", 0, 0, 10, 0, 13);
        let s = m.model_stats("mlp").unwrap();
        assert_eq!(s.batches, 2);
        assert_eq!((s.decode_fast_path, s.decode_voted), (150, 4));
        assert_eq!((s.faults_detected, s.faults_corrected, s.plans_adopted), (2, 1, 3));
        assert!(m.model_stats("nope").is_none());
        m.set_plan_store(
            StoreStats { builds: 16, hits: 48, evicted: 0, resident_plans: 16, resident_bytes: 4096 },
            vec![ModelPlanStats { model: "mlp".into(), hits: 9, misses: 3, plans: 3, bytes: 1024 }],
        );
        m.record_unload(2);
        m.set_fabric(FabricStats {
            helper_threads: 7,
            total_threads: 8,
            workers: 4,
            budget: 2,
            jobs: 11,
            tasks: 120,
        });
        m.energy_dac_conversions.add(500);
        m.energy_adc_conversions.add(700);
        m.energy_skipped_dac.add(60);
        m.energy_skipped_adc.add(40);
        m.set_gateway(GatewayReport {
            sessions_accepted: 9,
            sessions_active: 2,
            sessions_rejected: 1,
            frames_in: 40,
            frames_out: 41,
            protocol_errors: 3,
            http_scrapes: 5,
            latency_p50_us: 1000.0,
            latency_p99_us: 9000.0,
        });
        m.respawns.add(3);
        m.stalls.add(1);
        m.redispatched.add(2);
        m.poisoned.add(1);
        m.deadline_exceeded.add(4);
        let rep = m.report(Duration::from_secs(1));
        // global decode line precedes per-model lines (report parsers key
        // on the first `fast-path=` occurrence)
        assert!(rep.find("decode: fast-path=0").unwrap() < rep.find("model=bert").unwrap());
        assert!(rep.contains("unloads: proactive=1 worker-releases=2"), "{rep}");
        assert!(
            rep.contains(
                "supervision: respawns=3 stalls=1 redispatched=2 poisoned=1 deadline-exceeded=4"
            ),
            "{rep}"
        );
        // supervision renders with the global block, before per-model lines
        assert!(rep.find("supervision: respawns=").unwrap() < rep.find("model=bert").unwrap());
        assert!(
            rep.contains("fabric: threads=8 helpers=7 workers=4 budget=2 jobs=11 tasks=120"),
            "{rep}"
        );
        // BTreeMap => stable alphabetical model order
        assert!(rep.find("model=bert").unwrap() < rep.find("model=mlp").unwrap());
        assert!(rep.contains("model=mlp: batches=2 decode fast-path=150 voted=4"));
        assert!(rep.contains("plan store: resident=16 bytes=4096 builds=16 hits=48 evicted=0"));
        assert!(rep.contains("plan store model=mlp: resident=3 bytes=1024 hits=9 misses=3"));
        assert!(
            rep.contains(
                "energy: dac-conversions=500 adc-conversions=700 skipped-dac=60 skipped-adc=40"
            ),
            "{rep}"
        );
        assert!(
            rep.contains(
                "gateway: sessions=9 active=2 rejects=1 frames-in=40 frames-out=41 \
                 protocol-errors=3 scrapes=5"
            ),
            "{rep}"
        );
        assert!(rep.contains("gateway latency: p50=1000µs p99=9000µs"), "{rep}");
        // the gateway block renders after the PR-2 global lines, so old
        // parsers keyed on first occurrences are unaffected
        assert!(rep.find("decode: fast-path=0").unwrap() < rep.find("gateway: sessions=").unwrap());
    }

    #[test]
    fn report_and_exposition_read_the_same_counters() {
        let mut m = ServingMetrics::default();
        m.energy_adc_conversions.add(700);
        m.energy_dac_conversions.add(500);
        m.respawns.add(2);
        let rep = m.report(Duration::from_secs(1));
        let prom = m.render_prometheus();
        assert!(rep.contains("adc-conversions=700"), "{rep}");
        assert!(prom.contains("\nrns_adc_conversions_total 700\n"), "{prom}");
        assert!(prom.contains("\nrns_dac_conversions_total 500\n"), "{prom}");
        assert!(prom.contains("\nrns_supervision_respawns_total 2\n"), "{prom}");
        // decode exhausted is a first-class family and a report key
        assert!(rep.contains("decode: fast-path=0 voted=0 exhausted=0"), "{rep}");
        assert!(prom.contains("# TYPE rns_decode_exhausted_total counter"), "{prom}");
        // snapshot blocks sync into the registry at render time
        m.set_plan_store(
            StoreStats { builds: 4, hits: 9, evicted: 1, resident_plans: 3, resident_bytes: 640 },
            vec![],
        );
        let prom = m.render_prometheus();
        assert!(prom.contains("\nrns_plan_store_builds_total 4\n"), "{prom}");
        assert!(prom.contains("\nrns_plan_store_resident_bytes 640\n"), "{prom}");
    }

    #[test]
    fn trace_ring_keeps_the_slowest_and_renders_in_order() {
        let mut ring = TraceRing::new(2);
        let t = |id: u64, total: u64| RequestTrace {
            id,
            model: "mlp".into(),
            samples: 1,
            total_us: total,
            ..RequestTrace::default()
        };
        ring.offer(t(1, 100));
        ring.offer(t(2, 50));
        ring.offer(t(3, 200)); // evicts id=2 (fastest)
        ring.offer(t(4, 10)); // too fast: dropped
        assert_eq!(ring.len(), 2);
        let text = ring.render();
        assert!(text.starts_with("slow traces: kept=2 cap=2"), "{text}");
        let id3 = text.find("id=3").expect("slowest kept");
        let id1 = text.find("id=1").expect("second kept");
        assert!(id3 < id1, "slowest first: {text}");
        assert!(!text.contains("id=2"), "{text}");
        assert!(!text.contains("id=4"), "{text}");
    }

    #[test]
    fn trace_ring_interleaved_offers_retain_exactly_the_slowest_n() {
        let mut ring = TraceRing::new(4);
        let t = |id: u64, total: u64| RequestTrace {
            id,
            model: "mlp".into(),
            samples: 1,
            total_us: total,
            ..RequestTrace::default()
        };
        // slow and fast offers interleaved, ids deliberately unordered:
        // the retained set must be the 4 largest totals regardless of
        // arrival order or how often eviction ran
        for (id, total) in
            [(9, 70), (1, 500), (5, 30), (2, 400), (8, 60), (3, 300), (7, 20), (4, 200), (6, 10)]
        {
            ring.offer(t(id, total));
        }
        assert_eq!(ring.len(), 4);
        let text = ring.render();
        for kept in ["id=1", "id=2", "id=3", "id=4"] {
            assert!(text.contains(kept), "{text}");
        }
        for dropped in ["id=5", "id=6", "id=7", "id=8", "id=9"] {
            assert!(!text.contains(dropped), "{text}");
        }
        // slowest-first render order
        let pos = |needle: &str| text.find(needle).unwrap();
        assert!(pos("id=1") < pos("id=2") && pos("id=2") < pos("id=3"));
        assert!(pos("id=3") < pos("id=4"));
    }

    #[test]
    fn trace_ring_cap_zero_disables_cleanly() {
        let mut ring = TraceRing::new(0);
        ring.offer(RequestTrace {
            id: 1,
            model: "mlp".into(),
            samples: 1,
            total_us: 1_000_000,
            ..RequestTrace::default()
        });
        assert!(ring.is_empty());
        assert_eq!(ring.capacity(), 0);
        assert_eq!(ring.render(), "slow traces: kept=0 cap=0");
    }

    #[test]
    fn traces_append_to_the_report_after_every_existing_block() {
        let mut m = ServingMetrics::default();
        m.record_response(1, Duration::from_micros(120), Duration::from_micros(10), true);
        let before = m.report(Duration::from_secs(1));
        assert!(!before.contains("slow traces:"), "no trace lines when none recorded");
        m.record_trace(RequestTrace {
            id: 7,
            model: "mlp".into(),
            samples: 1,
            worker: 0,
            total_us: 120,
            queue_us: 10,
            batch_form_us: 2,
            dac_us: 20,
            gemm_us: 50,
            adc_us: 20,
            decode_us: 15,
            delivery_us: 3,
        });
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("slow traces: kept=1 cap=16"), "{rep}");
        assert!(
            rep.contains(
                "trace: id=7 model=mlp samples=1 worker=0 total=120µs queue=10µs form=2µs \
                 dac=20µs gemm=50µs adc=20µs decode=15µs delivery=3µs"
            ),
            "{rep}"
        );
        // appended strictly after the global lines
        assert!(rep.find("requests=").unwrap() < rep.find("slow traces:").unwrap());
        // trace capacity is adjustable and survivors persist
        m.set_trace_capacity(4);
        assert!(m.traces_report().contains("kept=1 cap=4"));
    }

    #[test]
    fn stage_histograms_share_one_family() {
        let m = ServingMetrics::default();
        m.stage.queue.observe(5);
        m.stage.decode.observe(10);
        // the gateway-side admission stage lands in the same family
        stage_histogram(&m.registry(), "admission").observe(1);
        let prom = m.render_prometheus();
        let type_lines =
            prom.lines().filter(|l| l.starts_with("# TYPE rns_stage_latency_us ")).count();
        assert_eq!(type_lines, 1, "one family: {prom}");
        for stage in ["queue", "decode", "admission"] {
            assert!(
                prom.contains(&format!("rns_stage_latency_us_count{{stage=\"{stage}\"}} 1")),
                "{prom}"
            );
        }
    }
}
