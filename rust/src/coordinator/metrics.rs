//! Serving metrics: throughput, latency percentiles, fault counters —
//! globally and per model — plus the shared plan store's hit/miss and
//! residency counters, the execution fabric's utilization, and the
//! control plane's proactive-unload counters in the shutdown report.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::runtime::fabric::FabricStats;
use crate::store::{ModelPlanStats, StoreStats};
use crate::util::stats::Reservoir;

/// Latency/queue/batch-size samples kept for percentile estimation.
/// Algorithm-R reservoirs bound the memory of a long-running server (the
/// PR-2 `Percentiles` vectors grew one entry per request forever); 4096
/// samples keep p99 well inside a percent of the exact value.
const RESERVOIR_CAP: usize = 4096;

/// Decode / fault / plan counters attributed to one model's batches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelServingStats {
    pub batches: u64,
    pub faults_detected: u64,
    pub faults_corrected: u64,
    pub decode_fast_path: u64,
    pub decode_voted: u64,
    /// Plans adopted by workers while serving this model (plateaus at
    /// workers × layers; the plan store's misses count is the
    /// deduplicated build side).
    pub plans_adopted: u64,
}

pub struct ServingMetrics {
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub failures: u64,
    pub faults_detected: u64,
    pub faults_corrected: u64,
    /// RRNS elements decoded by the batched no-fault fast path vs the
    /// per-element voting fallback (two-tier decode; fast/(fast+voted)
    /// near 1.0 is the healthy steady state for clean hardware).
    pub decode_fast_path: u64,
    pub decode_voted: u64,
    /// Per-layer RNS plans adopted across all workers (plateaus at
    /// workers × model layers — adoption is per worker; the shared plan
    /// store's `builds` counter shows the deduplicated build count).
    pub plans_built: u64,
    /// Data-converter activity summed across workers (exact integer
    /// conversion counts from each core's `EnergyMeter` — deterministic,
    /// which is what lets the gateway tests compare a served stream
    /// against the in-process path down to the converter count).
    pub energy_dac_conversions: u64,
    pub energy_adc_conversions: u64,
    /// Conversions sparse capture proved unnecessary and skipped (zero
    /// activations / structurally-zero output rows); always 0 unless the
    /// backend runs with `sparse_capture` on.
    pub energy_skipped_dac: u64,
    pub energy_skipped_adc: u64,
    /// Proactive unloads issued through the worker control plane, and
    /// how many worker-held model instances they released (a worker that
    /// never held the model acks without a release).
    pub unload_requests: u64,
    pub proactive_releases: u64,
    /// Supervision counters (PR 6): worker threads replaced (crash or
    /// stall), stalls among them, crashed in-flight batches replayed on a
    /// healthy slot, batches quarantined at the poison threshold, and
    /// requests failed with the typed `DeadlineExceeded`.
    pub respawns: u64,
    pub stalls: u64,
    pub redispatched: u64,
    pub poisoned: u64,
    pub deadline_exceeded: u64,
    /// Same counters keyed by model (BTreeMap: stable report order).
    per_model: BTreeMap<String, ModelServingStats>,
    /// Plan-store snapshot attached at shutdown.
    plan_store: Option<(StoreStats, Vec<ModelPlanStats>)>,
    /// Execution-fabric snapshot attached at shutdown (native RNS
    /// backends only).
    fabric: Option<FabricStats>,
    /// TCP gateway snapshot (sessions/frames/latency), attached by the
    /// gateway before it renders a live or shutdown report.
    gateway: Option<GatewayReport>,
    latency_us: Reservoir,
    queue_us: Reservoir,
    batch_sizes: Reservoir,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            requests: 0,
            samples: 0,
            batches: 0,
            failures: 0,
            faults_detected: 0,
            faults_corrected: 0,
            decode_fast_path: 0,
            decode_voted: 0,
            plans_built: 0,
            energy_dac_conversions: 0,
            energy_adc_conversions: 0,
            energy_skipped_dac: 0,
            energy_skipped_adc: 0,
            unload_requests: 0,
            proactive_releases: 0,
            respawns: 0,
            stalls: 0,
            redispatched: 0,
            poisoned: 0,
            deadline_exceeded: 0,
            per_model: BTreeMap::new(),
            plan_store: None,
            fabric: None,
            gateway: None,
            // fixed seeds: replacement decisions must not depend on how
            // many samples a previous run saw
            latency_us: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A7),
            queue_us: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A8),
            batch_sizes: Reservoir::new(RESERVOIR_CAP, 0x6A7E_11A9),
        }
    }
}

/// The TCP serving gateway's counters, rendered as `gateway:` report
/// lines.  Latency here is gateway-side request latency (submit →
/// response delivery), so it includes queueing + compute but not the
/// client's network hop.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GatewayReport {
    pub sessions_accepted: u64,
    pub sessions_active: u64,
    pub sessions_rejected: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub protocol_errors: u64,
    pub http_scrapes: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
}

impl ServingMetrics {
    pub fn record_batch(&mut self, batch_samples: usize) {
        self.batches += 1;
        self.batch_sizes.add(batch_samples as f64);
    }

    /// Accumulate one served batch's counter deltas under its model.
    #[allow(clippy::too_many_arguments)]
    pub fn record_model_batch(
        &mut self,
        model: &str,
        faults_detected: u64,
        faults_corrected: u64,
        decode_fast_path: u64,
        decode_voted: u64,
        plans_adopted: u64,
    ) {
        let e = self.per_model.entry(model.to_string()).or_default();
        e.batches += 1;
        e.faults_detected += faults_detected;
        e.faults_corrected += faults_corrected;
        e.decode_fast_path += decode_fast_path;
        e.decode_voted += decode_voted;
        e.plans_adopted += plans_adopted;
    }

    pub fn model_stats(&self, model: &str) -> Option<ModelServingStats> {
        self.per_model.get(model).copied()
    }

    /// Attach the shared plan store's counters for the shutdown report.
    pub fn set_plan_store(&mut self, stats: StoreStats, per_model: Vec<ModelPlanStats>) {
        self.plan_store = Some((stats, per_model));
    }

    /// Attach the shared execution fabric's shape + utilization counters
    /// for the shutdown report.
    pub fn set_fabric(&mut self, stats: FabricStats) {
        self.fabric = Some(stats);
    }

    /// Attach the TCP gateway's session/frame counters (rendered as
    /// `gateway:` lines after the global + per-model blocks).
    pub fn set_gateway(&mut self, g: GatewayReport) {
        self.gateway = Some(g);
    }

    /// Record one control-plane unload and how many worker-held
    /// instances it released.
    pub fn record_unload(&mut self, released: u64) {
        self.unload_requests += 1;
        self.proactive_releases += released;
    }

    pub fn record_response(&mut self, samples: usize, latency: Duration, queue: Duration, ok: bool) {
        self.requests += 1;
        self.samples += samples as u64;
        if !ok {
            self.failures += 1;
        }
        self.latency_us.add(latency.as_secs_f64() * 1e6);
        self.queue_us.add(queue.as_secs_f64() * 1e6);
    }

    pub fn latency_percentile_us(&mut self, q: f64) -> f64 {
        self.latency_us.percentile(q)
    }

    pub fn queue_percentile_us(&mut self, q: f64) -> f64 {
        self.queue_us.percentile(q)
    }

    pub fn mean_batch_size(&mut self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.batch_sizes.percentile(50.0) }
    }

    /// Render a one-screen report (used by `serve` and the e2e example).
    /// Global lines come first and keep their PR-2 shapes (parsers key on
    /// the first occurrence of `fast-path=` etc.); per-model decode lines
    /// and the plan-store block follow.
    pub fn report(&mut self, wall: Duration) -> String {
        let thpt = self.samples as f64 / wall.as_secs_f64().max(1e-9);
        let mb = self.mean_batch_size();
        let (p50, p95, p99) = (
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        );
        let q50 = self.queue_percentile_us(50.0);
        let mut out = format!(
            "requests={} samples={} batches={} failures={}\n\
             throughput={:.1} samples/s  median batch={:.1}\n\
             latency p50={:.0}µs p95={:.0}µs p99={:.0}µs  queue p50={:.0}µs\n\
             layer plans built={}\n\
             faults: detected={} corrected={}\n\
             decode: fast-path={} voted={}",
            self.requests,
            self.samples,
            self.batches,
            self.failures,
            thpt,
            mb,
            p50,
            p95,
            p99,
            q50,
            self.plans_built,
            self.faults_detected,
            self.faults_corrected,
            self.decode_fast_path,
            self.decode_voted,
        );
        // skipped-* appended after the PR-5 keys so parsers keyed on the
        // first dac-/adc-conversions occurrence keep working
        out.push_str(&format!(
            "\nenergy: dac-conversions={} adc-conversions={} skipped-dac={} skipped-adc={}",
            self.energy_dac_conversions,
            self.energy_adc_conversions,
            self.energy_skipped_dac,
            self.energy_skipped_adc,
        ));
        out.push_str(&format!(
            "\nunloads: proactive={} worker-releases={}",
            self.unload_requests, self.proactive_releases,
        ));
        out.push_str(&format!(
            "\nsupervision: respawns={} stalls={} redispatched={} poisoned={} \
             deadline-exceeded={}",
            self.respawns, self.stalls, self.redispatched, self.poisoned, self.deadline_exceeded,
        ));
        for (model, s) in &self.per_model {
            out.push_str(&format!(
                "\nmodel={model}: batches={} decode fast-path={} voted={} \
                 faults detected={} corrected={} plans adopted={}",
                s.batches,
                s.decode_fast_path,
                s.decode_voted,
                s.faults_detected,
                s.faults_corrected,
                s.plans_adopted,
            ));
        }
        if let Some((stats, per_model)) = &self.plan_store {
            out.push_str(&format!(
                "\nplan store: resident={} bytes={} builds={} hits={} evicted={}",
                stats.resident_plans, stats.resident_bytes, stats.builds, stats.hits, stats.evicted,
            ));
            for m in per_model {
                out.push_str(&format!(
                    "\nplan store model={}: resident={} bytes={} hits={} misses={}",
                    m.model, m.plans, m.bytes, m.hits, m.misses,
                ));
            }
        }
        if let Some(f) = &self.fabric {
            out.push_str(&format!(
                "\nfabric: threads={} helpers={} workers={} budget={} jobs={} tasks={}",
                f.total_threads, f.helper_threads, f.workers, f.budget, f.jobs, f.tasks,
            ));
        }
        if let Some(g) = &self.gateway {
            out.push_str(&format!(
                "\ngateway: sessions={} active={} rejects={} frames-in={} frames-out={} \
                 protocol-errors={} scrapes={}",
                g.sessions_accepted,
                g.sessions_active,
                g.sessions_rejected,
                g.frames_in,
                g.frames_out,
                g.protocol_errors,
                g.http_scrapes,
            ));
            out.push_str(&format!(
                "\ngateway latency: p50={:.0}µs p99={:.0}µs",
                g.latency_p50_us, g.latency_p99_us,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::default();
        m.record_batch(4);
        m.record_response(4, Duration::from_micros(100), Duration::from_micros(10), true);
        m.record_response(2, Duration::from_micros(300), Duration::from_micros(20), false);
        assert_eq!(m.requests, 2);
        assert_eq!(m.samples, 6);
        assert_eq!(m.failures, 1);
        let p50 = m.latency_percentile_us(50.0);
        assert!((p50 - 200.0).abs() < 1.0);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("throughput=6.0"));
        // the supervision line renders even when nothing went wrong
        assert!(
            rep.contains(
                "supervision: respawns=0 stalls=0 redispatched=0 poisoned=0 deadline-exceeded=0"
            ),
            "{rep}"
        );
    }

    #[test]
    fn latency_samples_are_bounded_by_the_reservoir() {
        // a long-running server must not grow a vector per request: the
        // reservoir caps retained samples while percentiles stay sane
        let mut m = ServingMetrics::default();
        for i in 0..100_000u64 {
            m.record_response(1, Duration::from_micros(i), Duration::from_micros(i / 2), true);
        }
        let p50 = m.latency_percentile_us(50.0);
        assert!((20_000.0..=80_000.0).contains(&p50), "p50 ={p50}");
        let p99 = m.latency_percentile_us(99.0);
        assert!(p99 > p50, "p99 {p99} above p50 {p50}");
        assert!(m.queue_percentile_us(50.0) < p50);
    }

    #[test]
    fn per_model_and_plan_store_sections() {
        let mut m = ServingMetrics::default();
        m.record_model_batch("mlp", 2, 1, 100, 4, 3);
        m.record_model_batch("mlp", 0, 0, 50, 0, 0);
        m.record_model_batch("bert", 0, 0, 10, 0, 13);
        let s = m.model_stats("mlp").unwrap();
        assert_eq!(s.batches, 2);
        assert_eq!((s.decode_fast_path, s.decode_voted), (150, 4));
        assert_eq!((s.faults_detected, s.faults_corrected, s.plans_adopted), (2, 1, 3));
        assert!(m.model_stats("nope").is_none());
        m.set_plan_store(
            StoreStats { builds: 16, hits: 48, evicted: 0, resident_plans: 16, resident_bytes: 4096 },
            vec![ModelPlanStats { model: "mlp".into(), hits: 9, misses: 3, plans: 3, bytes: 1024 }],
        );
        m.record_unload(2);
        m.set_fabric(FabricStats {
            helper_threads: 7,
            total_threads: 8,
            workers: 4,
            budget: 2,
            jobs: 11,
            tasks: 120,
        });
        m.energy_dac_conversions = 500;
        m.energy_adc_conversions = 700;
        m.energy_skipped_dac = 60;
        m.energy_skipped_adc = 40;
        m.set_gateway(GatewayReport {
            sessions_accepted: 9,
            sessions_active: 2,
            sessions_rejected: 1,
            frames_in: 40,
            frames_out: 41,
            protocol_errors: 3,
            http_scrapes: 5,
            latency_p50_us: 1000.0,
            latency_p99_us: 9000.0,
        });
        m.respawns = 3;
        m.stalls = 1;
        m.redispatched = 2;
        m.poisoned = 1;
        m.deadline_exceeded = 4;
        let rep = m.report(Duration::from_secs(1));
        // global decode line precedes per-model lines (report parsers key
        // on the first `fast-path=` occurrence)
        assert!(rep.find("decode: fast-path=0").unwrap() < rep.find("model=bert").unwrap());
        assert!(rep.contains("unloads: proactive=1 worker-releases=2"), "{rep}");
        assert!(
            rep.contains(
                "supervision: respawns=3 stalls=1 redispatched=2 poisoned=1 deadline-exceeded=4"
            ),
            "{rep}"
        );
        // supervision renders with the global block, before per-model lines
        assert!(rep.find("supervision: respawns=").unwrap() < rep.find("model=bert").unwrap());
        assert!(
            rep.contains("fabric: threads=8 helpers=7 workers=4 budget=2 jobs=11 tasks=120"),
            "{rep}"
        );
        // BTreeMap => stable alphabetical model order
        assert!(rep.find("model=bert").unwrap() < rep.find("model=mlp").unwrap());
        assert!(rep.contains("model=mlp: batches=2 decode fast-path=150 voted=4"));
        assert!(rep.contains("plan store: resident=16 bytes=4096 builds=16 hits=48 evicted=0"));
        assert!(rep.contains("plan store model=mlp: resident=3 bytes=1024 hits=9 misses=3"));
        assert!(
            rep.contains(
                "energy: dac-conversions=500 adc-conversions=700 skipped-dac=60 skipped-adc=40"
            ),
            "{rep}"
        );
        assert!(
            rep.contains(
                "gateway: sessions=9 active=2 rejects=1 frames-in=40 frames-out=41 \
                 protocol-errors=3 scrapes=5"
            ),
            "{rep}"
        );
        assert!(rep.contains("gateway latency: p50=1000µs p99=9000µs"), "{rep}");
        // the gateway block renders after the PR-2 global lines, so old
        // parsers keyed on first occurrences are unaffected
        assert!(rep.find("decode: fast-path=0").unwrap() < rep.find("gateway: sessions=").unwrap());
    }
}
