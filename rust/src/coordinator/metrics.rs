//! Serving metrics: throughput, latency percentiles, fault counters.

use std::time::Duration;

use crate::util::stats::Percentiles;

#[derive(Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub failures: u64,
    pub faults_detected: u64,
    pub faults_corrected: u64,
    /// RRNS elements decoded by the batched no-fault fast path vs the
    /// per-element voting fallback (two-tier decode; fast/(fast+voted)
    /// near 1.0 is the healthy steady state for clean hardware).
    pub decode_fast_path: u64,
    pub decode_voted: u64,
    /// Per-layer RNS plans built across all workers (should plateau at
    /// workers × model layers: plans are reused across requests).
    pub plans_built: u64,
    latency_us: Percentiles,
    queue_us: Percentiles,
    batch_sizes: Percentiles,
}

impl ServingMetrics {
    pub fn record_batch(&mut self, batch_samples: usize) {
        self.batches += 1;
        self.batch_sizes.add(batch_samples as f64);
    }

    pub fn record_response(&mut self, samples: usize, latency: Duration, queue: Duration, ok: bool) {
        self.requests += 1;
        self.samples += samples as u64;
        if !ok {
            self.failures += 1;
        }
        self.latency_us.add(latency.as_secs_f64() * 1e6);
        self.queue_us.add(queue.as_secs_f64() * 1e6);
    }

    pub fn latency_percentile_us(&mut self, q: f64) -> f64 {
        self.latency_us.percentile(q)
    }

    pub fn queue_percentile_us(&mut self, q: f64) -> f64 {
        self.queue_us.percentile(q)
    }

    pub fn mean_batch_size(&mut self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.batch_sizes.percentile(50.0) }
    }

    /// Render a one-screen report (used by `serve` and the e2e example).
    pub fn report(&mut self, wall: Duration) -> String {
        let thpt = self.samples as f64 / wall.as_secs_f64().max(1e-9);
        let mb = self.mean_batch_size();
        let (p50, p95, p99) = (
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        );
        let q50 = self.queue_percentile_us(50.0);
        format!(
            "requests={} samples={} batches={} failures={}\n\
             throughput={:.1} samples/s  median batch={:.1}\n\
             latency p50={:.0}µs p95={:.0}µs p99={:.0}µs  queue p50={:.0}µs\n\
             layer plans built={}\n\
             faults: detected={} corrected={}\n\
             decode: fast-path={} voted={}",
            self.requests,
            self.samples,
            self.batches,
            self.failures,
            thpt,
            mb,
            p50,
            p95,
            p99,
            q50,
            self.plans_built,
            self.faults_detected,
            self.faults_corrected,
            self.decode_fast_path,
            self.decode_voted,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServingMetrics::default();
        m.record_batch(4);
        m.record_response(4, Duration::from_micros(100), Duration::from_micros(10), true);
        m.record_response(2, Duration::from_micros(300), Duration::from_micros(20), false);
        assert_eq!(m.requests, 2);
        assert_eq!(m.samples, 6);
        assert_eq!(m.failures, 1);
        let p50 = m.latency_percentile_us(50.0);
        assert!((p50 - 200.0).abs() < 1.0);
        let rep = m.report(Duration::from_secs(1));
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("throughput=6.0"));
    }
}
