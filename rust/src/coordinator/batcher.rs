//! Dynamic batcher: groups queued requests for the same model into one
//! hardware batch, bounded by `max_batch` samples and `max_wait` age —
//! the standard serving trade-off (throughput vs tail latency) applied to
//! the analog core, whose MVM unit amortizes weight-DAC loads across the
//! batch.
//!
//! Grouping by model is also what makes prepared execution effective:
//! every sample in a formed batch hits the same per-layer `RnsPlan`s
//! (built once per worker at model-warm time, see server.rs), so the
//! coordinator reuses one plan per loaded model across all requests and
//! the engine's batch-row parallelism gets whole batches to split.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::request::InferenceRequest;
use crate::nn::models::Batch;
use crate::tensor::Nhwc;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max samples per formed batch.
    pub max_batch: usize,
    /// Max time the oldest request may wait before the batch is flushed.
    pub max_wait: Duration,
    /// How long a model's queue slot may sit empty before compaction
    /// removes it.  Slots are created on first sight of a name — unknown
    /// names included, since the load failure happens worker-side — so
    /// without compaction a gateway fed many distinct names grows one
    /// permanent slot per name and every `pop_ready` scans them all.
    /// Recently-emptied slots survive, preserving the oldest-queue-first
    /// flush priority for any model still in its serving cadence.
    pub compact_idle: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            compact_idle: Duration::from_secs(2),
        }
    }
}

/// A formed batch: the concatenated input plus the member requests and
/// their sample offsets (for splitting the logits back).
///
/// Each member carries its own `InferenceRequest::trace` id, so a
/// sampled request keeps its span-trace identity across batch formation
/// — the worker attributes per-stage spans back to every traced member
/// with `batch`/`member` args marking the shared execution.
pub struct FormedBatch {
    pub model: String,
    pub input: Batch,
    pub members: Vec<(InferenceRequest, usize)>, // (request, sample offset)
    /// How many workers this batch has crashed so far.  Incremented by
    /// the supervisor on each redispatch; at `poison_threshold` the batch
    /// is quarantined (typed `Poisoned` reject) instead of redispatched.
    pub crashes: u32,
    /// When `pop_ready` formed this batch: the boundary between a
    /// member's `queue` stage (submit → formation) and the batch's
    /// `batch_form` stage (formation → worker pickup) in the per-stage
    /// latency histograms and request traces.
    pub formed_at: Instant,
}

/// One model's FIFO slot (created on first sight of a model; removed
/// only by compaction after sitting empty for `compact_idle`, so slot
/// order is first-seen order for every model still in cadence).
struct ModelQueue {
    model: String,
    q: VecDeque<InferenceRequest>,
    /// When this queue last became empty (`None` while non-empty).
    empty_since: Option<Instant>,
}

/// Per-model FIFO with age- and size-triggered flushing.
///
/// Submit is O(1): `index` maps model name → slot.  Flushing scans the
/// slot vector in first-seen order, so when several models are ready the
/// *oldest queue* flushes first — the fairness property the
/// `flush_prefers_the_oldest_queue` regression test pins down (an
/// emptied queue keeps its slot, so a refilled model keeps its
/// priority).  Slots empty for longer than `compact_idle` are compacted
/// away (survivors keep their relative order; the index map is
/// renumbered), so a request stream naming many distinct models — e.g. a
/// gateway fed garbage names, which enqueue before the worker-side load
/// fails — cannot grow the scan set without bound.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: Vec<ModelQueue>,
    index: HashMap<String, usize>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, queues: Vec::new(), index: HashMap::new() }
    }

    pub fn push(&mut self, req: InferenceRequest) {
        match self.index.get(&req.model) {
            Some(&i) => {
                self.queues[i].empty_since = None;
                self.queues[i].q.push_back(req);
            }
            None => {
                let model = req.model.clone();
                self.index.insert(model.clone(), self.queues.len());
                let mut q = VecDeque::new();
                q.push_back(req);
                self.queues.push(ModelQueue { model, q, empty_since: None });
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|mq| mq.q.len()).sum()
    }

    /// Number of per-model queue slots currently held (compaction keeps
    /// this bounded by the set of recently-active models).
    pub fn model_slots(&self) -> usize {
        self.queues.len()
    }

    /// Drop slots that have sat empty for `compact_idle`, renumbering
    /// the index map without reordering survivors.  A compacted model
    /// that reappears starts a fresh slot at the back of the flush
    /// order — it left its serving cadence, so it re-queues like a new
    /// name (`flush_prefers_the_oldest_queue` only covers slots that
    /// refill within the idle window).
    fn compact(&mut self, now: Instant) {
        let idle = self.cfg.compact_idle;
        let stale = |mq: &ModelQueue| {
            mq.q.is_empty()
                && mq.empty_since.map(|t| now.duration_since(t) >= idle).unwrap_or(false)
        };
        if !self.queues.iter().any(stale) {
            return; // common case: nothing to do, no index rebuild
        }
        self.queues.retain(|mq| !stale(mq));
        self.index.clear();
        for (i, mq) in self.queues.iter().enumerate() {
            self.index.insert(mq.model.clone(), i);
        }
    }

    /// Pop a ready batch, if any queue hit `max_batch` samples or its head
    /// request is older than `max_wait` (or `force` drains regardless).
    pub fn pop_ready(&mut self, now: Instant, force: bool) -> Option<FormedBatch> {
        self.compact(now);
        let cfg = self.cfg;
        let idx = self.queues.iter().position(|mq| {
            let q = &mq.q;
            let samples: usize = q.iter().map(|r| r.num_samples()).sum();
            let head_age = q.front().map(|r| now.duration_since(r.submitted_at));
            (!q.is_empty())
                && (samples >= cfg.max_batch
                    || head_age.map(|a| a >= cfg.max_wait).unwrap_or(false)
                    || force)
        })?;
        let mq = &mut self.queues[idx];
        let model = mq.model.clone();
        let mut members = Vec::new();
        let mut samples = 0usize;
        while let Some(front) = mq.q.front() {
            let ns = front.num_samples();
            if !members.is_empty() && samples + ns > cfg.max_batch {
                break;
            }
            let req = mq.q.pop_front().unwrap();
            members.push((req, samples));
            samples += ns;
            if samples >= cfg.max_batch {
                break;
            }
        }
        if mq.q.is_empty() {
            mq.empty_since = Some(now); // compaction countdown starts now
        }
        let input = concat_inputs(members.iter().map(|(r, _)| &r.input));
        Some(FormedBatch { model, input, members, crashes: 0, formed_at: now })
    }

    /// Remove and return every queued request whose deadline has already
    /// passed — the dispatcher fails these with a typed
    /// `DeadlineExceeded` instead of spending analog-core time on
    /// answers nobody is waiting for.
    pub fn expire(&mut self, now: Instant) -> Vec<InferenceRequest> {
        let mut expired = Vec::new();
        for mq in &mut self.queues {
            let before = mq.q.len();
            let mut kept = VecDeque::with_capacity(before);
            for req in mq.q.drain(..) {
                if req.expired(now) {
                    expired.push(req);
                } else {
                    kept.push_back(req);
                }
            }
            mq.q = kept;
            if before > 0 && mq.q.is_empty() {
                mq.empty_since = Some(now);
            }
        }
        expired
    }
}

/// Concatenate request inputs along the batch axis (shapes must agree).
fn concat_inputs<'a, I: Iterator<Item = &'a Batch>>(inputs: I) -> Batch {
    let inputs: Vec<&Batch> = inputs.collect();
    assert!(!inputs.is_empty());
    match inputs[0] {
        Batch::Images(first) => {
            let (h, w, c) = (first.h, first.w, first.c);
            let mut data = Vec::new();
            let mut n = 0;
            for b in &inputs {
                match b {
                    Batch::Images(t) => {
                        assert_eq!((t.h, t.w, t.c), (h, w, c), "batch shape mismatch");
                        data.extend_from_slice(&t.data);
                        n += t.n;
                    }
                    _ => panic!("mixed input kinds in one batch"),
                }
            }
            Batch::Images(Nhwc::from_vec(n, h, w, c, data))
        }
        Batch::Tokens { seq, .. } => {
            let seq = *seq;
            let mut tokens = Vec::new();
            let mut batch = 0;
            for b in &inputs {
                match b {
                    Batch::Tokens { tokens: t, batch: bn, seq: s } => {
                        assert_eq!(*s, seq, "sequence length mismatch");
                        tokens.extend_from_slice(t);
                        batch += bn;
                    }
                    _ => panic!("mixed input kinds in one batch"),
                }
            }
            Batch::Tokens { tokens, batch, seq }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img_req(id: u64, model: &str, n: usize) -> InferenceRequest {
        InferenceRequest::new(id, model, Batch::Images(Nhwc::zeros(n, 2, 2, 1)))
    }

    fn cfg(max_batch: usize, max_wait: Duration) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait, ..Default::default() }
    }

    #[test]
    fn batches_by_size() {
        let mut b = DynamicBatcher::new(cfg(4, Duration::from_secs(10)));
        for i in 0..3 {
            b.push(img_req(i, "mlp", 1));
        }
        assert!(b.pop_ready(Instant::now(), false).is_none(), "3 < max_batch and young");
        b.push(img_req(3, "mlp", 1));
        let fb = b.pop_ready(Instant::now(), false).expect("full batch");
        assert_eq!(fb.members.len(), 4);
        assert_eq!(fb.input.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_age() {
        let mut b = DynamicBatcher::new(cfg(100, Duration::from_millis(0)));
        b.push(img_req(0, "mlp", 2));
        let fb = b.pop_ready(Instant::now() + Duration::from_millis(1), false).unwrap();
        assert_eq!(fb.input.len(), 2);
    }

    #[test]
    fn separates_models() {
        let mut b = DynamicBatcher::new(cfg(2, Duration::from_secs(10)));
        b.push(img_req(0, "mlp", 1));
        b.push(img_req(1, "cnn", 1));
        assert!(b.pop_ready(Instant::now(), false).is_none());
        b.push(img_req(2, "mlp", 1));
        let fb = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(fb.model, "mlp");
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flush_prefers_the_oldest_queue() {
        // regression for the index-map rewrite: when several models are
        // ready, the first-seen queue flushes first, and a queue that
        // emptied and refilled keeps its original slot (and priority)
        let mut b = DynamicBatcher::new(cfg(100, Duration::from_millis(0)));
        b.push(img_req(0, "a", 1));
        b.push(img_req(1, "b", 1));
        b.push(img_req(2, "c", 1));
        let later = Instant::now() + Duration::from_millis(1);
        assert_eq!(b.pop_ready(later, false).unwrap().model, "a");
        assert_eq!(b.pop_ready(later, false).unwrap().model, "b");
        // refill "a" after its queue emptied: it must flush before "c"
        b.push(img_req(3, "a", 1));
        let later = Instant::now() + Duration::from_millis(1);
        assert_eq!(
            b.pop_ready(later, false).unwrap().model,
            "a",
            "refilled queue keeps its first-seen slot"
        );
        assert_eq!(b.pop_ready(later, false).unwrap().model, "c");
        assert!(b.pop_ready(later, false).is_none());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn force_drains() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        b.push(img_req(0, "mlp", 1));
        assert!(b.pop_ready(Instant::now(), true).is_some());
    }

    #[test]
    fn long_empty_slots_compact_without_reordering_survivors() {
        // regression for slot compaction: a stream of one-shot model
        // names (e.g. garbage names fed to the gateway) must not grow a
        // permanent slot each, while slots still in cadence keep their
        // first-seen flush order
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(3600),
            compact_idle: Duration::from_millis(10),
        });
        let t0 = Instant::now();
        for i in 0..10 {
            b.push(img_req(i, &format!("spam-{i}"), 1));
        }
        for _ in 0..10 {
            assert!(b.pop_ready(t0, true).is_some());
        }
        assert_eq!(b.model_slots(), 10, "emptied slots linger until the idle window passes");
        b.push(img_req(20, "a", 1));
        b.push(img_req(21, "b", 1));
        assert_eq!(b.model_slots(), 12);
        // past the idle window: the 10 spam slots compact away, and the
        // survivors flush in their original relative order (a before b)
        let later = t0 + Duration::from_millis(50);
        assert_eq!(b.pop_ready(later, true).unwrap().model, "a");
        assert_eq!(b.model_slots(), 2, "compaction removed exactly the stale slots");
        assert_eq!(b.pop_ready(later, true).unwrap().model, "b");
        // a refill within the idle window reuses the surviving slot
        b.push(img_req(22, "a", 1));
        assert_eq!(b.model_slots(), 2);
        assert_eq!(b.pop_ready(later, true).unwrap().model, "a");
        // a compacted name returning starts a fresh slot at the back
        b.push(img_req(23, "spam-3", 1));
        assert_eq!(b.model_slots(), 3);
        let even_later = later + Duration::from_millis(50);
        // a and b sat empty since `later`: they compact now; spam-3 flushes
        assert_eq!(b.pop_ready(even_later, true).unwrap().model, "spam-3");
        assert_eq!(b.model_slots(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn offsets_track_sample_positions() {
        let mut b = DynamicBatcher::new(cfg(8, Duration::from_secs(10)));
        b.push(img_req(0, "mlp", 3));
        b.push(img_req(1, "mlp", 2));
        b.push(img_req(2, "mlp", 3));
        let fb = b.pop_ready(Instant::now(), false).unwrap();
        let offsets: Vec<usize> = fb.members.iter().map(|(_, o)| *o).collect();
        assert_eq!(offsets, vec![0, 3, 5]);
    }

    #[test]
    fn oversize_request_forms_own_batch() {
        let mut b = DynamicBatcher::new(cfg(2, Duration::from_secs(10)));
        b.push(img_req(0, "mlp", 5)); // bigger than max_batch
        let fb = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(fb.members.len(), 1);
        assert_eq!(fb.input.len(), 5);
    }

    #[test]
    fn expire_removes_only_past_deadline_requests() {
        let mut b = DynamicBatcher::new(cfg(100, Duration::from_secs(3600)));
        let now = Instant::now();
        b.push(img_req(0, "mlp", 1).with_deadline(Some(now + Duration::from_millis(5))));
        b.push(img_req(1, "mlp", 1)); // no deadline: never expires
        b.push(img_req(2, "cnn", 1).with_deadline(Some(now + Duration::from_secs(60))));
        assert!(b.expire(now).is_empty(), "nothing expired yet");
        let expired = b.expire(now + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 0);
        assert_eq!(b.pending(), 2, "unexpired requests stay queued");
        let later = now + Duration::from_millis(11);
        assert_eq!(b.pop_ready(later, true).unwrap().members[0].0.id, 1);
    }

    #[test]
    fn token_concat() {
        let mut b = DynamicBatcher::new(cfg(2, Duration::from_secs(10)));
        let t1 = Batch::Tokens { tokens: vec![1, 2], batch: 1, seq: 2 };
        let t2 = Batch::Tokens { tokens: vec![3, 4], batch: 1, seq: 2 };
        b.push(InferenceRequest::new(0, "bert", t1));
        b.push(InferenceRequest::new(1, "bert", t2));
        let fb = b.pop_ready(Instant::now(), false).unwrap();
        match fb.input {
            Batch::Tokens { tokens, batch, seq } => {
                assert_eq!(tokens, vec![1, 2, 3, 4]);
                assert_eq!((batch, seq), (2, 2));
            }
            _ => panic!(),
        }
    }
}
