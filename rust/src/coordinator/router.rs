//! Worker routing policies for the dispatcher.
//!
//! Round-robin is fair under uniform batches, but RRNS retries make batch
//! service times heavy-tailed (a noisy tile can take several recompute
//! attempts), so a least-outstanding policy keeps tail latency down.  The
//! ablation bench compares both under a noisy backend.

/// Tracks in-flight batches per worker and picks the next target.
pub trait RoutingPolicy: Send {
    /// Choose a worker in `0..workers` for the next batch.
    fn pick(&mut self, workers: usize) -> usize;
    /// A batch was dispatched to `worker`.
    fn on_dispatch(&mut self, worker: usize);
    /// A batch finished on `worker`.
    fn on_complete(&mut self, worker: usize);
    fn name(&self) -> &'static str;
}

/// Classic round-robin.
#[derive(Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn pick(&mut self, workers: usize) -> usize {
        // the cursor is kept in [0, workers) and advanced modulo the
        // worker count: a `wrapping_add` cursor would skip a slot when
        // it wraps at usize::MAX for counts that don't divide 2^64 (and
        // a shrinking worker set re-clamps instead of jumping)
        let w = workers.max(1);
        if self.next >= w {
            self.next %= w;
        }
        let pick = self.next;
        self.next = (self.next + 1) % w;
        pick
    }
    fn on_dispatch(&mut self, _worker: usize) {}
    fn on_complete(&mut self, _worker: usize) {}
    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Route to the worker with the fewest outstanding batches (ties -> lowest
/// index, so behaviour is deterministic).
#[derive(Default)]
pub struct LeastOutstanding {
    outstanding: Vec<usize>,
}

impl LeastOutstanding {
    fn ensure(&mut self, workers: usize) {
        if self.outstanding.len() < workers {
            self.outstanding.resize(workers, 0);
        }
    }
}

impl RoutingPolicy for LeastOutstanding {
    fn pick(&mut self, workers: usize) -> usize {
        self.ensure(workers);
        self.outstanding[..workers]
            .iter()
            .enumerate()
            .min_by_key(|(i, &o)| (o, *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_dispatch(&mut self, worker: usize) {
        self.ensure(worker + 1);
        self.outstanding[worker] += 1;
    }
    fn on_complete(&mut self, worker: usize) {
        self.ensure(worker + 1);
        self.outstanding[worker] = self.outstanding[worker].saturating_sub(1);
    }
    fn name(&self) -> &'static str {
        "least-outstanding"
    }
}

/// Policy selector for configs.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum RoutingKind {
    #[default]
    RoundRobin,
    LeastOutstanding,
}

impl RoutingKind {
    pub fn build(self) -> Box<dyn RoutingPolicy> {
        match self {
            RoutingKind::RoundRobin => Box::<RoundRobin>::default(),
            RoutingKind::LeastOutstanding => Box::<LeastOutstanding>::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.pick(3)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_has_no_seam_at_usize_max() {
        // 3 and 7 don't divide 2^64, so the old wrapping cursor skipped a
        // slot (or repeated one) when it wrapped; the rotation must stay
        // gap-free from any cursor value
        for workers in [3usize, 7] {
            let mut rr = RoundRobin { next: usize::MAX };
            let mut prev = rr.pick(workers);
            assert!(prev < workers);
            for _ in 0..3 * workers {
                let cur = rr.pick(workers);
                assert_eq!(cur, (prev + 1) % workers, "workers={workers}");
                prev = cur;
            }
        }
    }

    #[test]
    fn round_robin_reclamps_when_worker_set_shrinks() {
        let mut rr = RoundRobin::default();
        for _ in 0..5 {
            rr.pick(6);
        }
        // cursor is at 5; shrinking to 2 workers must clamp, not jump
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(2)).collect();
        assert_eq!(picks, vec![1, 0, 1, 0]);
    }

    #[test]
    fn least_outstanding_balances() {
        let mut lo = LeastOutstanding::default();
        let w0 = lo.pick(2);
        lo.on_dispatch(w0);
        let w1 = lo.pick(2);
        lo.on_dispatch(w1);
        assert_ne!(w0, w1, "second batch must go to the idle worker");
        // worker 0 finishes; next pick prefers it again
        lo.on_complete(w0);
        assert_eq!(lo.pick(2), w0);
    }

    #[test]
    fn least_outstanding_tracks_completion() {
        let mut lo = LeastOutstanding::default();
        // pile 3 batches on worker 0 only
        for _ in 0..3 {
            lo.on_dispatch(0);
        }
        assert_eq!(lo.pick(2), 1);
        for _ in 0..3 {
            lo.on_complete(0);
        }
        assert_eq!(lo.pick(2), 0);
        // completing an idle worker saturates at zero
        lo.on_complete(0);
        assert_eq!(lo.pick(2), 0);
    }

    #[test]
    fn kind_builds() {
        assert_eq!(RoutingKind::RoundRobin.build().name(), "round-robin");
        assert_eq!(RoutingKind::LeastOutstanding.build().name(), "least-outstanding");
    }
}
