//! Worker routing policies for the dispatcher.
//!
//! Round-robin is fair under uniform batches, but RRNS retries make batch
//! service times heavy-tailed (a noisy tile can take several recompute
//! attempts), so a least-outstanding policy keeps tail latency down.  The
//! ablation bench compares both under a noisy backend.

/// Tracks in-flight batches per worker and picks the next target.
pub trait RoutingPolicy: Send {
    /// Choose a worker in `0..workers` for the next batch.
    fn pick(&mut self, workers: usize) -> usize;
    /// A batch was dispatched to `worker`.
    fn on_dispatch(&mut self, worker: usize);
    /// A batch finished on `worker`.
    fn on_complete(&mut self, worker: usize);
    fn name(&self) -> &'static str;
}

/// Classic round-robin.
#[derive(Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn pick(&mut self, workers: usize) -> usize {
        let w = self.next % workers.max(1);
        self.next = self.next.wrapping_add(1);
        w
    }
    fn on_dispatch(&mut self, _worker: usize) {}
    fn on_complete(&mut self, _worker: usize) {}
    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Route to the worker with the fewest outstanding batches (ties -> lowest
/// index, so behaviour is deterministic).
#[derive(Default)]
pub struct LeastOutstanding {
    outstanding: Vec<usize>,
}

impl LeastOutstanding {
    fn ensure(&mut self, workers: usize) {
        if self.outstanding.len() < workers {
            self.outstanding.resize(workers, 0);
        }
    }
}

impl RoutingPolicy for LeastOutstanding {
    fn pick(&mut self, workers: usize) -> usize {
        self.ensure(workers);
        self.outstanding[..workers]
            .iter()
            .enumerate()
            .min_by_key(|(i, &o)| (o, *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_dispatch(&mut self, worker: usize) {
        self.ensure(worker + 1);
        self.outstanding[worker] += 1;
    }
    fn on_complete(&mut self, worker: usize) {
        self.ensure(worker + 1);
        self.outstanding[worker] = self.outstanding[worker].saturating_sub(1);
    }
    fn name(&self) -> &'static str {
        "least-outstanding"
    }
}

/// Policy selector for configs.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum RoutingKind {
    #[default]
    RoundRobin,
    LeastOutstanding,
}

impl RoutingKind {
    pub fn build(self) -> Box<dyn RoutingPolicy> {
        match self {
            RoutingKind::RoundRobin => Box::<RoundRobin>::default(),
            RoutingKind::LeastOutstanding => Box::<LeastOutstanding>::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.pick(3)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_outstanding_balances() {
        let mut lo = LeastOutstanding::default();
        let w0 = lo.pick(2);
        lo.on_dispatch(w0);
        let w1 = lo.pick(2);
        lo.on_dispatch(w1);
        assert_ne!(w0, w1, "second batch must go to the idle worker");
        // worker 0 finishes; next pick prefers it again
        lo.on_complete(w0);
        assert_eq!(lo.pick(2), w0);
    }

    #[test]
    fn least_outstanding_tracks_completion() {
        let mut lo = LeastOutstanding::default();
        // pile 3 batches on worker 0 only
        for _ in 0..3 {
            lo.on_dispatch(0);
        }
        assert_eq!(lo.pick(2), 1);
        for _ in 0..3 {
            lo.on_complete(0);
        }
        assert_eq!(lo.pick(2), 0);
        // completing an idle worker saturates at zero
        lo.on_complete(0);
        assert_eq!(lo.pick(2), 0);
    }

    #[test]
    fn kind_builds() {
        assert_eq!(RoutingKind::RoundRobin.build().name(), "round-robin");
        assert_eq!(RoutingKind::LeastOutstanding.build().name(), "least-outstanding");
    }
}
