//! Frozen evaluation datasets (exported by python/compile/train.py) and
//! synthetic workload generators for the benches.

use crate::nn::models::Batch;
use crate::nn::store::{self, StoredTensor};
use crate::tensor::{MatF, Nhwc};
use crate::util::rng::Rng;

/// A labelled evaluation set.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub input: Batch,
    pub labels: Vec<i64>,
}

impl EvalSet {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Take the first `n` examples (accuracy sweeps subsample for speed).
    pub fn take(&self, n: usize) -> EvalSet {
        let n = n.min(self.len());
        let input = match &self.input {
            Batch::Images(t) => {
                let stride = t.h * t.w * t.c;
                Batch::Images(Nhwc::from_vec(n, t.h, t.w, t.c, t.data[..n * stride].to_vec()))
            }
            Batch::Tokens { tokens, seq, .. } => {
                Batch::Tokens { tokens: tokens[..n * seq].to_vec(), batch: n, seq: *seq }
            }
        };
        EvalSet { input, labels: self.labels[..n].to_vec() }
    }
}

/// Dataset backing each zoo model (matches python/compile/train.py TASKS).
pub fn dataset_for_model(model: &str) -> &'static str {
    match model {
        "mlp" | "cnn" => "digits",
        "resnet" => "shapes",
        "bert" => "tokens",
        other => panic!("unknown model {other}"),
    }
}

/// Load `artifacts/data/<name>_eval.rt`.
pub fn load_eval_set(artifacts_dir: &str, name: &str) -> Result<EvalSet, String> {
    let path = format!("{artifacts_dir}/data/{name}_eval.rt");
    let s = store::load(&path).map_err(|e| e.to_string())?;
    let y = s
        .get("y")
        .and_then(|t| t.as_i64())
        .ok_or_else(|| format!("{path}: missing i64 labels `y`"))?
        .to_vec();
    let x = s.get("x").ok_or_else(|| format!("{path}: missing `x`"))?;
    let input = match x {
        StoredTensor::F32 { dims, data } => {
            if dims.len() != 4 {
                return Err(format!("{path}: image tensor must be NHWC, got {dims:?}"));
            }
            Batch::Images(Nhwc::from_vec(dims[0], dims[1], dims[2], dims[3], data.clone()))
        }
        StoredTensor::I64 { dims, data } => {
            if dims.len() != 2 {
                return Err(format!("{path}: token tensor must be (B, S), got {dims:?}"));
            }
            Batch::Tokens { tokens: data.clone(), batch: dims[0], seq: dims[1] }
        }
        _ => return Err(format!("{path}: unsupported input dtype")),
    };
    if input.len() != y.len() {
        return Err(format!("{path}: {} inputs vs {} labels", input.len(), y.len()));
    }
    Ok(EvalSet { input, labels: y })
}

/// Random dense GEMM operands (the Fig. 3 random-vector workload and the
/// bench harness's synthetic load).
pub fn random_gemm_pair(rng: &mut Rng, b: usize, k: usize, n: usize, scale: f32) -> (MatF, MatF) {
    let x = MatF::from_vec(b, k, (0..b * k).map(|_| rng.uniform_f32(-scale, scale)).collect());
    let w = MatF::from_vec(k, n, (0..k * n).map(|_| rng.uniform_f32(-scale, scale)).collect());
    (x, w)
}

/// Gaussian-ish vectors (Irwin–Hall sum of uniforms) used by Fig. 3 to
/// match the paper's "randomly generated vector pairs".
pub fn random_vector_pair(rng: &mut Rng, h: usize) -> (Vec<f32>, Vec<f32>) {
    let gauss = |rng: &mut Rng| -> f32 {
        ((0..4).map(|_| rng.uniform() as f32).sum::<f32>() - 2.0) * 0.866
    };
    ((0..h).map(|_| gauss(rng)).collect(), (0..h).map(|_| gauss(rng)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn take_subsamples() {
        let imgs = Nhwc::from_vec(4, 2, 2, 1, (0..16).map(|v| v as f32).collect());
        let set = EvalSet { input: Batch::Images(imgs), labels: vec![0, 1, 2, 3] };
        let sub = set.take(2);
        assert_eq!(sub.len(), 2);
        match &sub.input {
            Batch::Images(t) => {
                assert_eq!(t.n, 2);
                assert_eq!(t.data.len(), 8);
            }
            _ => panic!(),
        }
        // take more than available is clamped
        assert_eq!(set.take(100).len(), 4);
    }

    #[test]
    fn model_dataset_mapping() {
        assert_eq!(dataset_for_model("mlp"), "digits");
        assert_eq!(dataset_for_model("bert"), "tokens");
    }

    #[test]
    fn random_pair_shapes() {
        let mut rng = Rng::seed_from(0);
        let (x, w) = random_gemm_pair(&mut rng, 2, 8, 3, 1.0);
        assert_eq!((x.rows, x.cols, w.rows, w.cols), (2, 8, 8, 3));
        let (a, b) = random_vector_pair(&mut rng, 128);
        assert_eq!(a.len(), 128);
        assert_eq!(b.len(), 128);
        // roughly zero-mean
        let mean: f32 = a.iter().sum::<f32>() / 128.0;
        assert!(mean.abs() < 0.3);
    }

    #[test]
    fn loads_real_eval_sets_if_present() {
        let dir = artifacts_dir();
        if std::path::Path::new(&format!("{dir}/data/digits_eval.rt")).exists() {
            let set = load_eval_set(&dir, "digits").unwrap();
            assert_eq!(set.len(), 512);
            match &set.input {
                Batch::Images(t) => assert_eq!((t.h, t.w, t.c), (28, 28, 1)),
                _ => panic!("digits should be images"),
            }
            let tok = load_eval_set(&dir, "tokens").unwrap();
            match &tok.input {
                Batch::Tokens { seq, .. } => assert_eq!(*seq, 32),
                _ => panic!("tokens should be tokens"),
            }
        }
    }
}
