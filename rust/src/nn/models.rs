//! The evaluation model zoo: inference-only implementations of the models
//! trained by python/compile/train.py, loading RNSTORE1 weights.
//!
//! Each model implements `Model` and routes every weight GEMM through a
//! `GemmBackend`, so the Fig. 1/4/6 experiments evaluate the identical
//! network on FP32 / fixed-point-analog / RNS-analog hardware by swapping
//! the backend alone.

use crate::analog::GemmBackend;
use crate::nn::layers::{
    attention_single, conv2d, dense, gelu, global_avg_pool, layernorm, maxpool2, relu_mat,
    relu_nhwc,
};
use crate::nn::store::{f32_tensor, TensorStore};
use crate::tensor::im2col::Padding;
use crate::tensor::{MatF, Nhwc};

/// Batched model input: images or token sequences.
#[derive(Clone, Debug)]
pub enum Batch {
    Images(Nhwc),
    Tokens { tokens: Vec<i64>, batch: usize, seq: usize },
}

impl Batch {
    pub fn len(&self) -> usize {
        match self {
            Batch::Images(t) => t.n,
            Batch::Tokens { batch, .. } => *batch,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A loadable inference model.
pub trait Model: Send + Sync {
    fn name(&self) -> &'static str;
    /// Logits (B, num_classes).
    fn forward(&self, input: &Batch, backend: &mut dyn GemmBackend) -> MatF;
    fn num_classes(&self) -> usize;
    /// FP32 eval accuracy recorded at training time (from the store).
    fn trained_fp32_accuracy(&self) -> f32;
    /// Pre-build backend per-layer state (RNS plans: weight quantization,
    /// per-channel residues, u32 staging, weight-DAC accounting) for every
    /// weight GEMM this model issues.  Weights are stationary, so the
    /// coordinator calls this once per (worker, model) right after load —
    /// all later requests reuse the plans.  Default: nothing.
    fn warm(&self, _backend: &mut dyn GemmBackend) {}
}

fn get_mat(store: &TensorStore, name: &str, rows: usize, cols: usize) -> Result<MatF, String> {
    let data = f32_tensor(store, name, Some(&[rows, cols]))?;
    Ok(MatF::from_vec(rows, cols, data.to_vec()))
}

fn get_vec(store: &TensorStore, name: &str, len: usize) -> Result<Vec<f32>, String> {
    Ok(f32_tensor(store, name, Some(&[len]))?.to_vec())
}

/// Conv weights stored HWIO (kh, kw, cin, cout) -> (kh*kw*cin, cout).
fn get_conv(store: &TensorStore, name: &str, kh: usize, kw: usize, cin: usize, cout: usize) -> Result<MatF, String> {
    let data = f32_tensor(store, name, Some(&[kh, kw, cin, cout]))?;
    Ok(MatF::from_vec(kh * kw * cin, cout, data.to_vec()))
}

fn stored_accuracy(store: &TensorStore) -> f32 {
    store
        .get("__fp32_eval_acc")
        .and_then(|t| t.as_f32())
        .and_then(|d| d.first().copied())
        .unwrap_or(0.0)
}

fn argmax_rows(logits: &MatF) -> Vec<usize> {
    (0..logits.rows)
        .map(|r| {
            logits
                .row(r)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

/// Classification accuracy of a model over a labelled batch.
pub fn accuracy(model: &dyn Model, input: &Batch, labels: &[i64], backend: &mut dyn GemmBackend) -> f64 {
    let logits = model.forward(input, backend);
    let preds = argmax_rows(&logits);
    let hits = preds.iter().zip(labels).filter(|(p, l)| **p as i64 == **l).count();
    hits as f64 / labels.len() as f64
}

// ---------------------------------------------------------------------------
// MLP (784 -> 256 -> 128 -> 10)
// ---------------------------------------------------------------------------

pub struct Mlp {
    ws: Vec<MatF>,
    bs: Vec<Vec<f32>>,
    acc: f32,
}

pub const MLP_DIMS: [usize; 4] = [784, 256, 128, 10];

impl Mlp {
    pub fn from_store(store: &TensorStore) -> Result<Self, String> {
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for i in 0..MLP_DIMS.len() - 1 {
            ws.push(get_mat(store, &format!("fc{i}.w"), MLP_DIMS[i], MLP_DIMS[i + 1])?);
            bs.push(get_vec(store, &format!("fc{i}.b"), MLP_DIMS[i + 1])?);
        }
        Ok(Mlp { ws, bs, acc: stored_accuracy(store) })
    }

    /// Synthetic-weight MLP (seeded uniform weights in ±0.1, no
    /// artifacts): lets drift campaigns, examples, and tests push a
    /// real full-model forward through a backend without the python
    /// `make artifacts` step.  Deterministic in `seed`.
    pub fn synthetic(seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for i in 0..MLP_DIMS.len() - 1 {
            let (r, c) = (MLP_DIMS[i], MLP_DIMS[i + 1]);
            ws.push(MatF::from_vec(
                r,
                c,
                (0..r * c).map(|_| rng.uniform_f32(-0.1, 0.1)).collect(),
            ));
            bs.push((0..c).map(|_| rng.uniform_f32(-0.1, 0.1)).collect());
        }
        Mlp { ws, bs, acc: 0.0 }
    }
}

impl Model for Mlp {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn forward(&self, input: &Batch, backend: &mut dyn GemmBackend) -> MatF {
        let imgs = match input {
            Batch::Images(t) => t,
            _ => panic!("mlp expects image input"),
        };
        let mut h = imgs.flatten();
        for (i, (w, b)) in self.ws.iter().zip(&self.bs).enumerate() {
            h = dense(&h, w, b, backend);
            if i + 1 < self.ws.len() {
                relu_mat(&mut h);
            }
        }
        h
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn trained_fp32_accuracy(&self) -> f32 {
        self.acc
    }

    fn warm(&self, backend: &mut dyn GemmBackend) {
        for w in &self.ws {
            backend.prepare(w);
        }
    }
}

// ---------------------------------------------------------------------------
// Two-layer CNN (paper Fig. 1's MNIST model)
// ---------------------------------------------------------------------------

pub struct TwoLayerCnn {
    conv1_w: MatF,
    conv1_b: Vec<f32>,
    conv2_w: MatF,
    conv2_b: Vec<f32>,
    fc_w: MatF,
    fc_b: Vec<f32>,
    acc: f32,
}

impl TwoLayerCnn {
    pub fn from_store(store: &TensorStore) -> Result<Self, String> {
        Ok(TwoLayerCnn {
            conv1_w: get_conv(store, "conv1.w", 3, 3, 1, 8)?,
            conv1_b: get_vec(store, "conv1.b", 8)?,
            conv2_w: get_conv(store, "conv2.w", 3, 3, 8, 16)?,
            conv2_b: get_vec(store, "conv2.b", 16)?,
            fc_w: get_mat(store, "fc.w", 7 * 7 * 16, 10)?,
            fc_b: get_vec(store, "fc.b", 10)?,
            acc: stored_accuracy(store),
        })
    }
}

impl Model for TwoLayerCnn {
    fn name(&self) -> &'static str {
        "cnn"
    }

    fn forward(&self, input: &Batch, backend: &mut dyn GemmBackend) -> MatF {
        let imgs = match input {
            Batch::Images(t) => t,
            _ => panic!("cnn expects image input"),
        };
        let mut h = conv2d(imgs, &self.conv1_w, &self.conv1_b, 3, 3, Padding::Same, backend);
        relu_nhwc(&mut h);
        let mut h = maxpool2(&h);
        let mut h2 = conv2d(&h, &self.conv2_w, &self.conv2_b, 3, 3, Padding::Same, backend);
        relu_nhwc(&mut h2);
        h = maxpool2(&h2);
        let flat = h.flatten();
        dense(&flat, &self.fc_w, &self.fc_b, backend)
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn trained_fp32_accuracy(&self) -> f32 {
        self.acc
    }

    fn warm(&self, backend: &mut dyn GemmBackend) {
        for w in [&self.conv1_w, &self.conv2_w, &self.fc_w] {
            backend.prepare(w);
        }
    }
}

// ---------------------------------------------------------------------------
// MiniResNet (ResNet50 stand-in, see DESIGN.md §5)
// ---------------------------------------------------------------------------

pub const RESNET_WIDTH: usize = 16;
pub const RESNET_BLOCKS: usize = 3;

pub struct MiniResNet {
    stem_w: MatF,
    stem_b: Vec<f32>,
    blocks: Vec<(MatF, Vec<f32>, MatF, Vec<f32>)>,
    fc_w: MatF,
    fc_b: Vec<f32>,
    acc: f32,
}

impl MiniResNet {
    pub fn from_store(store: &TensorStore) -> Result<Self, String> {
        let w = RESNET_WIDTH;
        let mut blocks = Vec::new();
        for bidx in 0..RESNET_BLOCKS {
            blocks.push((
                get_conv(store, &format!("block{bidx}_conv1.w"), 3, 3, w, w)?,
                get_vec(store, &format!("block{bidx}_conv1.b"), w)?,
                get_conv(store, &format!("block{bidx}_conv2.w"), 3, 3, w, w)?,
                get_vec(store, &format!("block{bidx}_conv2.b"), w)?,
            ));
        }
        Ok(MiniResNet {
            stem_w: get_conv(store, "stem.w", 3, 3, 3, w)?,
            stem_b: get_vec(store, "stem.b", w)?,
            blocks,
            fc_w: get_mat(store, "fc.w", w, 10)?,
            fc_b: get_vec(store, "fc.b", 10)?,
            acc: stored_accuracy(store),
        })
    }
}

impl Model for MiniResNet {
    fn name(&self) -> &'static str {
        "resnet"
    }

    fn forward(&self, input: &Batch, backend: &mut dyn GemmBackend) -> MatF {
        let imgs = match input {
            Batch::Images(t) => t,
            _ => panic!("resnet expects image input"),
        };
        let mut h = conv2d(imgs, &self.stem_w, &self.stem_b, 3, 3, Padding::Same, backend);
        relu_nhwc(&mut h);
        for (w1, b1, w2, b2) in &self.blocks {
            let mut r = conv2d(&h, w1, b1, 3, 3, Padding::Same, backend);
            relu_nhwc(&mut r);
            let r2 = conv2d(&r, w2, b2, 3, 3, Padding::Same, backend);
            for (hv, rv) in h.data.iter_mut().zip(&r2.data) {
                *hv = (*hv + rv).max(0.0); // residual add + relu
            }
        }
        let pooled = global_avg_pool(&h);
        dense(&pooled, &self.fc_w, &self.fc_b, backend)
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn trained_fp32_accuracy(&self) -> f32 {
        self.acc
    }

    fn warm(&self, backend: &mut dyn GemmBackend) {
        backend.prepare(&self.stem_w);
        for (w1, _, w2, _) in &self.blocks {
            backend.prepare(w1);
            backend.prepare(w2);
        }
        backend.prepare(&self.fc_w);
    }
}

// ---------------------------------------------------------------------------
// TinyBert (BERT-large stand-in, see DESIGN.md §5)
// ---------------------------------------------------------------------------

pub const BERT_VOCAB: usize = 32;
pub const BERT_SEQ: usize = 32;
pub const BERT_DIM: usize = 64;
pub const BERT_HEADS: usize = 4;
pub const BERT_FFN: usize = 128;
pub const BERT_LAYERS: usize = 2;
pub const BERT_CLASSES: usize = 4;

struct BertLayer {
    wq: (MatF, Vec<f32>),
    wk: (MatF, Vec<f32>),
    wv: (MatF, Vec<f32>),
    wo: (MatF, Vec<f32>),
    ffn1: (MatF, Vec<f32>),
    ffn2: (MatF, Vec<f32>),
    ln1: (Vec<f32>, Vec<f32>),
    ln2: (Vec<f32>, Vec<f32>),
}

pub struct TinyBert {
    embed: MatF, // (VOCAB, DIM)
    pos: MatF,   // (SEQ, DIM)
    layers: Vec<BertLayer>,
    cls: (MatF, Vec<f32>),
    acc: f32,
}

impl TinyBert {
    pub fn from_store(store: &TensorStore) -> Result<Self, String> {
        let d = BERT_DIM;
        let mut layers = Vec::new();
        for l in 0..BERT_LAYERS {
            let pair = |n: &str, rows: usize, cols: usize| -> Result<(MatF, Vec<f32>), String> {
                Ok((
                    get_mat(store, &format!("l{l}_{n}.w"), rows, cols)?,
                    get_vec(store, &format!("l{l}_{n}.b"), cols)?,
                ))
            };
            layers.push(BertLayer {
                wq: pair("wq", d, d)?,
                wk: pair("wk", d, d)?,
                wv: pair("wv", d, d)?,
                wo: pair("wo", d, d)?,
                ffn1: pair("ffn1", d, BERT_FFN)?,
                ffn2: pair("ffn2", BERT_FFN, d)?,
                ln1: (get_vec(store, &format!("l{l}_ln1.g"), d)?, get_vec(store, &format!("l{l}_ln1.b"), d)?),
                ln2: (get_vec(store, &format!("l{l}_ln2.g"), d)?, get_vec(store, &format!("l{l}_ln2.b"), d)?),
            });
        }
        Ok(TinyBert {
            embed: get_mat(store, "embed", BERT_VOCAB, d)?,
            pos: get_mat(store, "pos", BERT_SEQ, d)?,
            layers,
            cls: (get_mat(store, "cls.w", d, BERT_CLASSES)?, get_vec(store, "cls.b", BERT_CLASSES)?),
            acc: stored_accuracy(store),
        })
    }

    /// Forward one sequence (S, D) through the encoder stack.
    fn encode(&self, mut h: MatF, backend: &mut dyn GemmBackend) -> MatF {
        for layer in &self.layers {
            let q = dense(&h, &layer.wq.0, &layer.wq.1, backend);
            let k = dense(&h, &layer.wk.0, &layer.wk.1, backend);
            let v = dense(&h, &layer.wv.0, &layer.wv.1, backend);
            let att = attention_single(&q, &k, &v, BERT_HEADS);
            let att = dense(&att, &layer.wo.0, &layer.wo.1, backend);
            for (hv, av) in h.data.iter_mut().zip(&att.data) {
                *hv += av;
            }
            layernorm(&mut h, &layer.ln1.0, &layer.ln1.1, 1e-5);
            let mut f = dense(&h, &layer.ffn1.0, &layer.ffn1.1, backend);
            gelu(&mut f);
            let f = dense(&f, &layer.ffn2.0, &layer.ffn2.1, backend);
            for (hv, fv) in h.data.iter_mut().zip(&f.data) {
                *hv += fv;
            }
            layernorm(&mut h, &layer.ln2.0, &layer.ln2.1, 1e-5);
        }
        h
    }
}

impl Model for TinyBert {
    fn name(&self) -> &'static str {
        "bert"
    }

    fn forward(&self, input: &Batch, backend: &mut dyn GemmBackend) -> MatF {
        let (tokens, batch, seq) = match input {
            Batch::Tokens { tokens, batch, seq } => (tokens, *batch, *seq),
            _ => panic!("bert expects token input"),
        };
        assert_eq!(seq, BERT_SEQ);
        let mut logits = MatF::zeros(batch, BERT_CLASSES);
        for b in 0..batch {
            let mut h = MatF::zeros(seq, BERT_DIM);
            for s in 0..seq {
                let tok = tokens[b * seq + s] as usize % BERT_VOCAB;
                for d in 0..BERT_DIM {
                    h.set(s, d, self.embed.at(tok, d) + self.pos.at(s, d));
                }
            }
            let h = self.encode(h, backend);
            // mean pool over sequence
            let mut pooled = MatF::zeros(1, BERT_DIM);
            for s in 0..seq {
                for d in 0..BERT_DIM {
                    pooled.data[d] += h.at(s, d);
                }
            }
            for v in pooled.data.iter_mut() {
                *v /= seq as f32;
            }
            let out = dense(&pooled, &self.cls.0, &self.cls.1, backend);
            logits.row_mut(b).copy_from_slice(out.row(0));
        }
        logits
    }

    fn num_classes(&self) -> usize {
        BERT_CLASSES
    }

    fn trained_fp32_accuracy(&self) -> f32 {
        self.acc
    }

    fn warm(&self, backend: &mut dyn GemmBackend) {
        for layer in &self.layers {
            for w in [
                &layer.wq.0,
                &layer.wk.0,
                &layer.wv.0,
                &layer.wo.0,
                &layer.ffn1.0,
                &layer.ffn2.0,
            ] {
                backend.prepare(w);
            }
        }
        backend.prepare(&self.cls.0);
    }
}

/// Seeded synthetic model names servable without `make artifacts`
/// (loopback gateway tests, CI smoke traffic, benches).  `SYNTHETIC_MLP`
/// loads `Mlp::synthetic(1)` through the normal registry path, so it
/// batches, warms plans, and unloads exactly like a trained model.
pub const SYNTHETIC_MLP: &str = "synthetic-mlp";

/// Load any zoo model by name from `artifacts/models/<name>.rt`
/// (`SYNTHETIC_MLP` is generated in-process instead).
pub fn load_model(artifacts_dir: &str, name: &str) -> Result<Box<dyn Model>, String> {
    if name == SYNTHETIC_MLP {
        return Ok(Box::new(Mlp::synthetic(1)));
    }
    let path = format!("{artifacts_dir}/models/{name}.rt");
    let store = crate::nn::store::load(&path).map_err(|e| e.to_string())?;
    match name {
        "mlp" => Ok(Box::new(Mlp::from_store(&store)?)),
        "cnn" => Ok(Box::new(TwoLayerCnn::from_store(&store)?)),
        "resnet" => Ok(Box::new(MiniResNet::from_store(&store)?)),
        "bert" => Ok(Box::new(TinyBert::from_store(&store)?)),
        other => Err(format!("unknown model `{other}`")),
    }
}

/// Shared, load-once model registry: every coordinator worker clones one
/// `Arc<dyn Model>` per model instead of loading its own copy.  Besides
/// de-duplicating weight memory W-fold, this is what makes the shared
/// `PlanStore` de-duplicate plans — plan keys include the weight
/// allocation's address, so workers must literally share the weights for
/// their plan lookups to collide (see `store::PlanKey`).
pub struct ModelRegistry {
    artifacts_dir: String,
    /// Name -> `Once`-style load cell, the same slot-reservation pattern
    /// as `store::PlanStore`: the map lock is only held to reserve or
    /// look up a cell, never across the filesystem load, so a cold load
    /// of one model cannot stall workers serving other models.
    models: std::sync::Mutex<std::collections::HashMap<String, ModelCell>>,
}

type ModelCell = std::sync::Arc<std::sync::OnceLock<Result<std::sync::Arc<dyn Model>, String>>>;

impl ModelRegistry {
    pub fn new(artifacts_dir: &str) -> Self {
        ModelRegistry {
            artifacts_dir: artifacts_dir.to_string(),
            models: std::sync::Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Fetch a model, loading it at most once across all workers.
    /// Concurrent first requests for the *same* model serialize on its
    /// cell (one filesystem load, everyone clones the result); requests
    /// for other models only touch the map lock briefly.  A failed load
    /// is not cached: its slot is dropped so a later request retries
    /// (e.g. after the operator regenerates artifacts).
    pub fn get_or_load(&self, name: &str) -> Result<std::sync::Arc<dyn Model>, String> {
        let cell = {
            let mut models = self.models.lock().unwrap();
            std::sync::Arc::clone(
                models
                    .entry(name.to_string())
                    .or_insert_with(|| std::sync::Arc::new(std::sync::OnceLock::new())),
            )
        };
        let result = cell
            .get_or_init(|| load_model(&self.artifacts_dir, name).map(std::sync::Arc::from))
            .clone();
        if result.is_err() {
            let mut models = self.models.lock().unwrap();
            // drop the failed slot only if it is still ours — a concurrent
            // unload + reload may have installed a fresh cell already
            if models.get(name).is_some_and(|c| std::sync::Arc::ptr_eq(c, &cell)) {
                models.remove(name);
            }
        }
        result
    }

    /// Peek at a loaded instance without triggering a load (`None` if
    /// the name is absent, failed, or still loading).  The release hook
    /// the coordinator's proactive-unload test builds on: grab a clone,
    /// unload, and watch `Arc::strong_count` fall as workers ack.
    pub fn peek(&self, name: &str) -> Option<std::sync::Arc<dyn Model>> {
        let models = self.models.lock().unwrap();
        models.get(name)?.get()?.as_ref().ok().cloned()
    }

    /// Drop the shared instance; weights free once the last worker's
    /// clone drops.  Pair with `PlanStore::unload_model` to evict the
    /// model's plans too (`Coordinator::unload_model` does both and then
    /// releases worker-held clones through the control plane).  Returns
    /// whether a loaded instance was
    /// dropped.  A cell whose load is still in flight is left
    /// registered: removing it would orphan the instance the loader is
    /// about to hand its caller (a second request would then load a
    /// duplicate allocation, and the orphan's plans could be pinned
    /// under the tag with no unload path).  The completing load is
    /// equivalent to a reload issued right after this unload; call
    /// `unload` again to drop it.
    pub fn unload(&self, name: &str) -> bool {
        let mut models = self.models.lock().unwrap();
        let loaded = match models.get(name) {
            None => return false,
            Some(cell) => match cell.get() {
                None => return false, // in-flight: leave registered
                Some(r) => r.is_ok(),
            },
        };
        models.remove(name);
        loaded
    }

    /// Names currently resident (successfully loaded), sorted.
    pub fn loaded(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .models
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, c)| c.get().is_some_and(|r| r.is_ok()))
            .map(|(k, _)| k.clone())
            .collect();
        names.sort();
        names
    }
}

pub const ZOO: [&str; 4] = ["mlp", "cnn", "resnet", "bert"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Fp32Backend;
    use crate::nn::store::{StoredTensor, TensorStore};
    use crate::util::rng::Rng;

    fn synth_store(entries: &[(&str, Vec<usize>)]) -> TensorStore {
        let mut rng = Rng::seed_from(0);
        let mut store = TensorStore::new();
        for (name, dims) in entries {
            let n: usize = dims.iter().product();
            store.insert(
                name.to_string(),
                StoredTensor::F32 {
                    dims: dims.clone(),
                    data: (0..n).map(|_| rng.uniform_f32(-0.1, 0.1)).collect(),
                },
            );
        }
        store
    }

    #[test]
    fn mlp_forward_shape_from_synthetic_weights() {
        let store = synth_store(&[
            ("fc0.w", vec![784, 256]),
            ("fc0.b", vec![256]),
            ("fc1.w", vec![256, 128]),
            ("fc1.b", vec![128]),
            ("fc2.w", vec![128, 10]),
            ("fc2.b", vec![10]),
        ]);
        let mlp = Mlp::from_store(&store).unwrap();
        let imgs = Nhwc::zeros(3, 28, 28, 1);
        let out = mlp.forward(&Batch::Images(imgs), &mut Fp32Backend);
        assert_eq!((out.rows, out.cols), (3, 10));
    }

    #[test]
    fn warm_builds_one_plan_per_weight_gemm() {
        use crate::analog::{RnsCore, RnsCoreConfig};
        let store = synth_store(&[
            ("fc0.w", vec![784, 256]),
            ("fc0.b", vec![256]),
            ("fc1.w", vec![256, 128]),
            ("fc1.b", vec![128]),
            ("fc2.w", vec![128, 10]),
            ("fc2.b", vec![10]),
        ]);
        let mlp = Mlp::from_store(&store).unwrap();
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(4, 128)).unwrap();
        mlp.warm(&mut core);
        assert_eq!(GemmBackend::plans_built(&core), 3);
        // a forward pass reuses the warm plans instead of building more
        let imgs = Nhwc::zeros(2, 28, 28, 1);
        mlp.forward(&Batch::Images(imgs), &mut core);
        assert_eq!(GemmBackend::plans_built(&core), 3);
        // the fp32 backend has no per-layer state: warm is a no-op
        let mut fp32 = Fp32Backend;
        mlp.warm(&mut fp32);
        assert_eq!(fp32.plans_built(), 0);
    }

    #[test]
    fn registry_loads_once_and_unloads() {
        let reg = ModelRegistry::new("/nonexistent");
        assert!(reg.get_or_load("mlp").is_err(), "no artifacts -> load error");
        assert!(reg.get_or_load("no-such-model").is_err());
        assert!(reg.loaded().is_empty());
        assert!(reg.peek("mlp").is_none(), "failed loads are not peekable");
        assert!(!reg.unload("mlp"));
        // with real artifacts the shared instance is pointer-equal
        let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        if std::path::Path::new(&format!("{dir}/models/mlp.rt")).exists() {
            let reg = ModelRegistry::new(&dir);
            let a = reg.get_or_load("mlp").unwrap();
            let b = reg.get_or_load("mlp").unwrap();
            assert!(std::sync::Arc::ptr_eq(&a, &b), "one load, shared Arc");
            let p = reg.peek("mlp").expect("peek sees the loaded instance");
            assert!(std::sync::Arc::ptr_eq(&a, &p), "peek returns the same Arc, no reload");
            assert_eq!(reg.loaded(), vec!["mlp".to_string()]);
            assert!(reg.unload("mlp"));
            assert!(reg.peek("mlp").is_none(), "unload drops the registry's clone");
        }
    }

    #[test]
    fn missing_weight_is_error() {
        let store = synth_store(&[("fc0.w", vec![784, 256])]);
        assert!(Mlp::from_store(&store).is_err());
    }

    #[test]
    fn wrong_shape_is_error() {
        let store = synth_store(&[
            ("fc0.w", vec![10, 10]),
            ("fc0.b", vec![256]),
            ("fc1.w", vec![256, 128]),
            ("fc1.b", vec![128]),
            ("fc2.w", vec![128, 10]),
            ("fc2.b", vec![10]),
        ]);
        assert!(Mlp::from_store(&store).is_err());
    }

    #[test]
    fn argmax_rows_basics() {
        let m = MatF::from_vec(2, 3, vec![0.1, 0.9, 0.3, 0.5, 0.2, 0.1]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn accuracy_computation() {
        struct Fixed;
        impl Model for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn forward(&self, input: &Batch, _b: &mut dyn GemmBackend) -> MatF {
                let n = input.len();
                let mut m = MatF::zeros(n, 2);
                for r in 0..n {
                    m.set(r, r % 2, 1.0); // predicts 0,1,0,1,...
                }
                m
            }
            fn num_classes(&self) -> usize {
                2
            }
            fn trained_fp32_accuracy(&self) -> f32 {
                1.0
            }
        }
        let imgs = Nhwc::zeros(4, 1, 1, 1);
        let acc = accuracy(&Fixed, &Batch::Images(imgs), &[0, 1, 1, 1], &mut Fp32Backend);
        assert!((acc - 0.75).abs() < 1e-9);
    }
}
