//! RNSTORE1 tensor container — rust reader/writer for the binary format
//! produced by `python/compile/tensorstore.py` (trained weights + frozen
//! eval sets).  See that file for the byte layout.

use std::collections::BTreeMap;
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"RNSTORE1";

/// A stored tensor: shape + typed payload.
#[derive(Clone, Debug, PartialEq)]
pub enum StoredTensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I64 { dims: Vec<usize>, data: Vec<i64> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
}

impl StoredTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            StoredTensor::F32 { dims, .. }
            | StoredTensor::I64 { dims, .. }
            | StoredTensor::U8 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            StoredTensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<&[i64]> {
        match self {
            StoredTensor::I64 { data, .. } => Some(data),
            _ => None,
        }
    }
}

pub type TensorStore = BTreeMap<String, StoredTensor>;

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn bad(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Load a store from a file path.
pub fn load(path: &str) -> std::io::Result<TensorStore> {
    let file = std::fs::File::open(path)?;
    let mut r = std::io::BufReader::new(file);
    load_from(&mut r).map_err(|e| bad(format!("{path}: {e}")))
}

/// Load a store from any reader.
pub fn load_from(r: &mut impl Read) -> std::io::Result<TensorStore> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic".into()));
    }
    let count = read_u32(r)?;
    let mut out = TensorStore::new();
    for _ in 0..count {
        let nlen = read_u32(r)? as usize;
        if nlen > 4096 {
            return Err(bad(format!("implausible name length {nlen}")));
        }
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|e| bad(e.to_string()))?;
        let mut code = [0u8; 1];
        r.read_exact(&mut code)?;
        let ndim = read_u32(r)? as usize;
        if ndim > 8 {
            return Err(bad(format!("{name}: implausible ndim {ndim}")));
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(r)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
        let tensor = match code[0] {
            0 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                let data = buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                StoredTensor::F32 { dims, data }
            }
            1 => {
                let mut buf = vec![0u8; n * 8];
                r.read_exact(&mut buf)?;
                let data = buf
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                StoredTensor::I64 { dims, data }
            }
            2 => {
                let mut data = vec![0u8; n];
                r.read_exact(&mut data)?;
                StoredTensor::U8 { dims, data }
            }
            c => return Err(bad(format!("{name}: unknown dtype code {c}"))),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

/// Write a store (used by round-trip tests and the rust-side exporters).
pub fn save(path: &str, store: &TensorStore) -> std::io::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(store.len() as u32).to_le_bytes())?;
    for (name, t) in store {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        let (code, dims): (u8, &[usize]) = match t {
            StoredTensor::F32 { dims, .. } => (0, dims),
            StoredTensor::I64 { dims, .. } => (1, dims),
            StoredTensor::U8 { dims, .. } => (2, dims),
        };
        w.write_all(&[code])?;
        w.write_all(&(dims.len() as u32).to_le_bytes())?;
        for &d in dims {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            StoredTensor::F32 { data, .. } => {
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            StoredTensor::I64 { data, .. } => {
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            StoredTensor::U8 { data, .. } => w.write_all(data)?,
        }
    }
    Ok(())
}

/// Fetch a required f32 tensor with shape validation.
pub fn f32_tensor<'a>(
    store: &'a TensorStore,
    name: &str,
    expect_dims: Option<&[usize]>,
) -> Result<&'a [f32], String> {
    let t = store.get(name).ok_or_else(|| format!("missing tensor `{name}`"))?;
    if let Some(want) = expect_dims {
        if t.dims() != want {
            return Err(format!("`{name}`: dims {:?} != expected {:?}", t.dims(), want));
        }
    }
    t.as_f32().ok_or_else(|| format!("`{name}` is not f32"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut store = TensorStore::new();
        store.insert(
            "a.w".into(),
            StoredTensor::F32 { dims: vec![2, 3], data: vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.0] },
        );
        store.insert("y".into(), StoredTensor::I64 { dims: vec![4], data: vec![-1, 0, 5, 9] });
        store.insert("b".into(), StoredTensor::U8 { dims: vec![2, 2], data: vec![0, 255, 7, 8] });
        let dir = std::env::temp_dir().join("rns_store_test.rt");
        let path = dir.to_str().unwrap();
        save(path, &store).unwrap();
        let back = load(path).unwrap();
        assert_eq!(back, store);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let mut data: Vec<u8> = b"NOTMAGIC".to_vec();
        data.extend_from_slice(&0u32.to_le_bytes());
        assert!(load_from(&mut data.as_slice()).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let mut data: Vec<u8> = MAGIC.to_vec();
        data.extend_from_slice(&1u32.to_le_bytes());
        data.extend_from_slice(&3u32.to_le_bytes());
        data.extend_from_slice(b"ab"); // name shorter than declared
        assert!(load_from(&mut data.as_slice()).is_err());
    }

    #[test]
    fn f32_tensor_helper() {
        let mut store = TensorStore::new();
        store.insert("w".into(), StoredTensor::F32 { dims: vec![2], data: vec![1.0, 2.0] });
        assert!(f32_tensor(&store, "w", Some(&[2])).is_ok());
        assert!(f32_tensor(&store, "w", Some(&[3])).is_err());
        assert!(f32_tensor(&store, "nope", None).is_err());
    }

    #[test]
    fn reads_python_written_model() {
        // integration with the python writer: load a real artifact if built
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/models/mlp.rt");
        if std::path::Path::new(path).exists() {
            let store = load(path).unwrap();
            assert!(store.contains_key("fc0.w"));
            let t = store.get("fc0.w").unwrap();
            assert_eq!(t.dims(), &[784, 256]);
        }
    }
}
