//! Inference layers, all GEMMs routed through a `GemmBackend` so the same
//! model runs on FP32, fixed-point-analog, or RNS-analog hardware.
//!
//! Numerics mirror python/compile/model.py (NHWC conv via im2col, tanh-GELU,
//! eps-1e-5 LayerNorm) so rust FP32 inference reproduces the jax training
//! accuracy.

use crate::analog::GemmBackend;
use crate::tensor::gemm::gemm_f32;
use crate::tensor::im2col::{col2im, conv_out_dim, im2col, Padding};
use crate::tensor::{MatF, Nhwc};

/// Dense: y = x @ w + b through the backend.  For per-layer backend state
/// (RNS plans), `Model::warm` calls `backend.prepare(w)` on every weight
/// matrix ahead of time so the first inference pays no plan-build latency.
pub fn dense(x: &MatF, w: &MatF, b: &[f32], backend: &mut dyn GemmBackend) -> MatF {
    assert_eq!(w.cols, b.len());
    let mut y = backend.gemm(x, w);
    for r in 0..y.rows {
        let row = y.row_mut(r);
        for (v, &bias) in row.iter_mut().zip(b) {
            *v += bias;
        }
    }
    y
}

/// Conv2d, stride 1, NHWC/HWIO, via im2col + backend GEMM.
pub fn conv2d(
    input: &Nhwc,
    w: &MatF, // (kh*kw*cin, cout) — HWIO flattened
    b: &[f32],
    kh: usize,
    kw: usize,
    pad: Padding,
    backend: &mut dyn GemmBackend,
) -> Nhwc {
    let patches = im2col(input, kh, kw, 1, pad);
    let y = dense(&patches, w, b, backend);
    let oh = conv_out_dim(input.h, kh, 1, pad);
    let ow = conv_out_dim(input.w, kw, 1, pad);
    col2im(&y, input.n, oh, ow)
}

/// 2x2 max pool, stride 2, VALID.
pub fn maxpool2(input: &Nhwc) -> Nhwc {
    let oh = input.h / 2;
    let ow = input.w / 2;
    let mut out = Nhwc::zeros(input.n, oh, ow, input.c);
    for b in 0..input.n {
        for y in 0..oh {
            for x in 0..ow {
                for c in 0..input.c {
                    let m = input
                        .at(b, 2 * y, 2 * x, c)
                        .max(input.at(b, 2 * y, 2 * x + 1, c))
                        .max(input.at(b, 2 * y + 1, 2 * x, c))
                        .max(input.at(b, 2 * y + 1, 2 * x + 1, c));
                    out.set(b, y, x, c, m);
                }
            }
        }
    }
    out
}

/// Global average pool: NHWC -> (N, C).
pub fn global_avg_pool(input: &Nhwc) -> MatF {
    let mut out = MatF::zeros(input.n, input.c);
    let denom = (input.h * input.w) as f32;
    for b in 0..input.n {
        for y in 0..input.h {
            for x in 0..input.w {
                for c in 0..input.c {
                    out.data[b * input.c + c] += input.at(b, y, x, c);
                }
            }
        }
    }
    for v in out.data.iter_mut() {
        *v /= denom;
    }
    out
}

pub fn relu_mat(x: &mut MatF) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
    }
}

pub fn relu_nhwc(x: &mut Nhwc) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
    }
}

/// tanh-approximation GELU (matches model.py bit-for-bit closely).
pub fn gelu(x: &mut MatF) {
    for v in x.data.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.797_884_56_f32 * (*v + 0.044715 * x3)).tanh());
    }
}

/// Row-wise softmax (numerically stable).
pub fn softmax_rows(x: &mut MatF) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// LayerNorm over the last axis with learned gain/bias.
pub fn layernorm(x: &mut MatF, g: &[f32], b: &[f32], eps: f32) {
    assert_eq!(x.cols, g.len());
    assert_eq!(x.cols, b.len());
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let mean = row.iter().sum::<f32>() / row.len() as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (v, (&gi, &bi)) in row.iter_mut().zip(g.iter().zip(b)) {
            *v = (*v - mean) * inv * gi + bi;
        }
    }
}

/// Multi-head self-attention for one (S, D) sequence already projected to
/// q/k/v — helper used by the TinyBert model.  Projections are done by the
/// caller (through the backend); the score/value matmuls here use FP32
/// (they are activation-activation products; see DESIGN.md — weight-side
/// GEMMs dominate the analog workload).
pub fn attention_single(q: &MatF, k: &MatF, v: &MatF, heads: usize) -> MatF {
    let (s, d) = (q.rows, q.cols);
    assert_eq!(d % heads, 0);
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = MatF::zeros(s, d);
    for h in 0..heads {
        let c0 = h * hd;
        let c1 = c0 + hd;
        let qh = q.slice_cols(c0, c1);
        let kh = k.slice_cols(c0, c1);
        let vh = v.slice_cols(c0, c1);
        let mut scores = gemm_f32(&qh, &kh.transpose());
        for val in scores.data.iter_mut() {
            *val *= scale;
        }
        softmax_rows(&mut scores);
        let oh = gemm_f32(&scores, &vh);
        for r in 0..s {
            out.row_mut(r)[c0..c1].copy_from_slice(oh.row(r));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Fp32Backend;

    #[test]
    fn dense_adds_bias() {
        let x = MatF::from_vec(1, 2, vec![1.0, 2.0]);
        let w = MatF::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = dense(&x, &w, &[10.0, 20.0], &mut Fp32Backend);
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn maxpool_known() {
        let input = Nhwc::from_vec(1, 2, 2, 1, vec![1.0, 5.0, 3.0, 2.0]);
        let out = maxpool2(&input);
        assert_eq!(out.data, vec![5.0]);
    }

    #[test]
    fn gap_average() {
        let input = Nhwc::from_vec(1, 2, 2, 2, vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let out = global_avg_pool(&input);
        assert_eq!(out.data, vec![2.5, 25.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = MatF::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for r in 0..2 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x.at(0, 2) > x.at(0, 1));
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = MatF::from_vec(1, 2, vec![1000.0, 1001.0]);
        softmax_rows(&mut x);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut x = MatF::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        layernorm(&mut x, &[1.0; 4], &[0.0; 4], 1e-5);
        let mean: f32 = x.data.iter().sum::<f32>() / 4.0;
        let var: f32 = x.data.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_reference_points() {
        let mut x = MatF::from_vec(1, 3, vec![0.0, 1.0, -1.0]);
        gelu(&mut x);
        assert_eq!(x.data[0], 0.0);
        assert!((x.data[1] - 0.8412).abs() < 1e-3);
        assert!((x.data[2] + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn attention_uniform_when_scores_equal() {
        // identical q/k rows -> uniform attention -> output = mean of v
        let q = MatF::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        let k = q.clone();
        let v = MatF::from_vec(2, 2, vec![0.0, 2.0, 4.0, 6.0]);
        let out = attention_single(&q, &k, &v, 1);
        for r in 0..2 {
            assert!((out.at(r, 0) - 2.0).abs() < 1e-6);
            assert!((out.at(r, 1) - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 conv with identity weights passes channels through
        let input = Nhwc::from_vec(1, 2, 2, 2, (0..8).map(|v| v as f32).collect());
        let mut w = MatF::zeros(2, 2);
        w.set(0, 0, 1.0);
        w.set(1, 1, 1.0);
        let out = conv2d(&input, &w, &[0.0, 0.0], 1, 1, Padding::Same, &mut Fp32Backend);
        assert_eq!(out.data, input.data);
    }
}
