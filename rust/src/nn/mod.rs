//! NN substrate: RNSTORE1 weight/dataset loading, inference layers routed
//! through pluggable `GemmBackend`s, and the evaluation model zoo
//! (MLP / TwoLayerCnn / MiniResNet / TinyBert — the MLPerf stand-ins of
//! DESIGN.md §5).

pub mod dataset;
pub mod layers;
pub mod models;
pub mod store;

pub use dataset::{load_eval_set, EvalSet};
pub use models::{accuracy, load_model, Batch, Model, ZOO};
