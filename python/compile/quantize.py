"""Paper §III-B scaling + quantization (build-time jax implementation).

Mirrors rust/src/quant/.  The dataflow (Fig. 2):

  s_in  = max(|X_HP|)                      (one scalar per input vector)
  s_w[r] = max(|W_HP[r,:]|)                (one scalar per weight row)
  X_LP = round(X_HP / s_in  * (2^(b-1)-1))  in [-(2^(b-1)-1), 2^(b-1)-1]
  W_LP = round(W_HP / s_w   * (2^(b-1)-1))
  residues = X_LP mod m_i   (negatives wrap through M)
  ... modular matmul ... CRT ...
  Y[k] = Y_SI[k] * s_in * s_w[k] / (2^(b-1)-1)^2

Note the convention: the MVM here is X @ W with W of shape (K, N); the
paper's per-row scaling of the h x h weight matrix corresponds to scaling
per *output* column in this layout (each output neuron k has scale s_w[k]),
matching `Y[k] = Y_SI[k] * s_in * s_w[k]`.
"""

from __future__ import annotations

import jax.numpy as jnp


def qmax(bits: int) -> float:
    """Largest symmetric quantized magnitude: 2^(b-1) - 1."""
    return float((1 << (bits - 1)) - 1)


def quantize_activations(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-vector symmetric quantization.  x: (B, K) -> (q, s_in) with
    q integer-valued f32 in [-qmax, qmax] and s_in: (B, 1)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.where(s == 0, 1.0, s)
    q = jnp.round(x / s * qmax(bits))
    return q, s


def quantize_weights(w: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-column symmetric quantization.  w: (K, N) -> (q, s_w) with
    s_w: (1, N) (paper: one scale per row of the h x h matrix = per output)."""
    s = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    s = jnp.where(s == 0, 1.0, s)
    q = jnp.round(w / s * qmax(bits))
    return q, s


def to_residues(q: jnp.ndarray, moduli: jnp.ndarray) -> jnp.ndarray:
    """Signed integer-valued f32 -> residue channels, shape (n, *q.shape).

    Negative values wrap: a_i = ((q mod m_i) + m_i) mod m_i.  Exact for
    |q| < 2^23 (true for quantized values, |q| <= 127)."""
    m = moduli.reshape((-1,) + (1,) * q.ndim)
    r = jnp.mod(q[None], m)
    return jnp.where(r < 0, r + m, r)


def dequantize(y_si: jnp.ndarray, s_in: jnp.ndarray, s_w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Y_SI (B, N) integer-valued -> float output, undoing both scalings."""
    return y_si * s_in * s_w / (qmax(bits) ** 2)
