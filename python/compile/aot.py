"""AOT export: lower the L2 entry points to HLO *text* for the rust runtime.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exported per bit-width b in 4..8 (Table-I moduli, h = 128):
  rns_mvm_b{b}.hlo.txt     — the pallas modular matmul alone:
                             (x_res f32[n,B,K], w_res f32[n,K,N]) -> f32[n,B,N]
  rns_gemm_b{b}.hlo.txt    — the full Fig. 2 pipeline:
                             (x f32[B,K], w f32[K,N]) -> f32[B,N]
  fixed_point_b{b}.hlo.txt — the baseline core with ADC truncation.
  model.hlo.txt            — alias of rns_gemm_b6 (the paper's headline
                             configuration) for the Makefile contract.
  manifest.txt             — key=value metadata the rust loader parses
                             (shapes, moduli, batch) without needing serde.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import RnsGemmConfig, fixed_point_gemm, rns_gemm
from .kernels.rns_matmul import rns_matmul

BATCH = 8
H = 128
BITS = range(4, 9)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_rns_mvm(cfg: RnsGemmConfig):
    mods = jnp.asarray(cfg.moduli, jnp.float32)

    def fn(x_res, w_res):
        return (rns_matmul(x_res, w_res, mods),)

    n = len(cfg.moduli)
    xs = jax.ShapeDtypeStruct((n, BATCH, H), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, H, H), jnp.float32)
    return jax.jit(fn).lower(xs, ws)


def lower_rns_gemm(cfg: RnsGemmConfig):
    def fn(x, w):
        return (rns_gemm(x, w, cfg),)

    xs = jax.ShapeDtypeStruct((BATCH, H), jnp.float32)
    ws = jax.ShapeDtypeStruct((H, H), jnp.float32)
    return jax.jit(fn).lower(xs, ws)


def lower_fixed_point(bits: int):
    def fn(x, w):
        return (fixed_point_gemm(x, w, bits, H),)

    xs = jax.ShapeDtypeStruct((BATCH, H), jnp.float32)
    ws = jax.ShapeDtypeStruct((H, H), jnp.float32)
    return jax.jit(fn).lower(xs, ws)


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[str] = [f"batch={BATCH}", f"h={H}"]
    for b in BITS:
        cfg = RnsGemmConfig.for_bits(b, H)
        n = len(cfg.moduli)
        manifest.append(f"moduli_b{b}={','.join(str(m) for m in cfg.moduli)}")
        for name, lowered in (
            (f"rns_mvm_b{b}", lower_rns_mvm(cfg)),
            (f"rns_gemm_b{b}", lower_rns_gemm(cfg)),
            (f"fixed_point_b{b}", lower_fixed_point(b)),
        ):
            text = to_hlo_text(lowered)
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {path} ({len(text)} chars, n={n})")
    # Makefile contract: artifacts/model.hlo.txt is the headline config.
    import shutil

    shutil.copyfile(
        os.path.join(out_dir, "rns_gemm_b6.hlo.txt"), os.path.join(out_dir, "model.hlo.txt")
    )
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true", help="HLO export only")
    args = ap.parse_args()
    export(args.out)
    from .export_golden import export as export_golden

    export_golden(args.out)
    if not args.skip_train:
        from .train import export_all

        export_all(args.out)


if __name__ == "__main__":
    main()
