"""L2 — jax compute graphs: the RNS GEMM pipeline and the evaluation models.

Two roles:
  1. `rns_gemm` / `fixed_point_gemm`: the paper's Fig. 2 dataflow as a
     single jitted graph (quantize -> residues -> pallas modular matmul ->
     CRT -> dequantize).  `aot.py` lowers these to HLO text for the rust
     runtime.
  2. Plain-f32 model definitions (MLP / TwoLayerCnn / MiniResNet /
     TinyBert) used by `train.py` to produce the trained weights that the
     rust accuracy experiments (Figs. 1, 4, 6) evaluate.

CRT needs exact integer arithmetic up to M^2-ish magnitudes (~2^32 for
Table-I sets), beyond f32's 2^24 window, so x64 is enabled and the CRT runs
in f64 (exact below 2^53).  Training code pins f32 explicitly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from . import quantize as q
from .kernels.rns_matmul import exact_mod, fixed_point_matmul, rns_matmul
from .rnsmath import RnsContext, required_output_bits, select_moduli


# --------------------------------------------------------------------------
# The paper's RNS GEMM pipeline (Fig. 2)
# --------------------------------------------------------------------------


class RnsGemmConfig(NamedTuple):
    bits: int
    moduli: tuple[int, ...]

    @classmethod
    def for_bits(cls, bits: int, h: int = 128) -> "RnsGemmConfig":
        return cls(bits=bits, moduli=tuple(select_moduli(bits, h)))


def crt_f64(res: jnp.ndarray, ctx: RnsContext) -> jnp.ndarray:
    """Eq. (1) in f64: residues (n, ...) -> signed integers (...).

    Every intermediate stays below n * m_max * M < 2^34 << 2^53, so f64
    arithmetic is exact; `exact_mod` guards the one division."""
    coeff = jnp.asarray(ctx.crt_coeff, jnp.float64)
    big_m = float(ctx.big_m)
    acc = jnp.zeros(res.shape[1:], jnp.float64)
    for i in range(ctx.n):
        acc = exact_mod(acc + res[i].astype(jnp.float64) * coeff[i], big_m)
    return jnp.where(acc > big_m // 2, acc - big_m, acc)


@functools.partial(jax.jit, static_argnames=("cfg",))
def rns_gemm(x: jnp.ndarray, w: jnp.ndarray, cfg: RnsGemmConfig) -> jnp.ndarray:
    """Full RNS analog-core dataflow: f32 (B,K) x (K,N) -> f32 (B,N).

    The modular matmul (the analog part) runs in the pallas kernel; the
    scaling, forward conversion, CRT and rescale are the digital wrapper
    exactly as in Fig. 2.
    """
    ctx = RnsContext(list(cfg.moduli))
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    xq, s_in = q.quantize_activations(x, cfg.bits)
    wq, s_w = q.quantize_weights(w, cfg.bits)
    mods = jnp.asarray(cfg.moduli, jnp.float32)
    xr = q.to_residues(xq, mods)                      # (n, B, K)
    wr = q.to_residues(wq, mods)                      # (n, K, N)
    out_res = rns_matmul(xr, wr, mods)                # (n, B, N) in [0, m_i)
    y_si = crt_f64(out_res, ctx)                      # signed integers
    return q.dequantize(y_si.astype(jnp.float32), s_in, s_w, cfg.bits)


@functools.partial(jax.jit, static_argnames=("bits", "h"))
def fixed_point_gemm(x: jnp.ndarray, w: jnp.ndarray, bits: int, h: int | None = None) -> jnp.ndarray:
    """Baseline: regular fixed-point analog core with b_adc = bits ADCs.

    Drops b_out - bits LSBs of every partial dot product (paper Table I,
    right half)."""
    k = x.shape[-1]
    b_out = required_output_bits(bits, bits, h or k)
    dropped = max(b_out - bits, 0)
    xq, s_in = q.quantize_activations(x.astype(jnp.float32), bits)
    wq, s_w = q.quantize_weights(w.astype(jnp.float32), bits)
    y = fixed_point_matmul(xq, wq, dropped)
    return q.dequantize(y, s_in, s_w, bits)


# --------------------------------------------------------------------------
# Evaluation models (trained in f32 by train.py, evaluated in rust)
# --------------------------------------------------------------------------


def _dense_init(key, fan_in: int, fan_out: int):
    wkey, _ = jax.random.split(key)
    scale = float(np.sqrt(2.0 / fan_in))
    return {
        "w": (jax.random.normal(wkey, (fan_in, fan_out)) * scale).astype(jnp.float32),
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def _conv_init(key, kh: int, kw: int, cin: int, cout: int):
    scale = float(np.sqrt(2.0 / (kh * kw * cin)))
    return {
        "w": (jax.random.normal(key, (kh, kw, cin, cout)) * scale).astype(jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv2d(x: jnp.ndarray, p: dict, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    """NHWC conv with HWIO weights — the layout the rust im2col mirrors."""
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def layernorm(x: jnp.ndarray, p: dict, eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh approximation — matches the rust implementation bit-for-bit
    # closely enough for accuracy experiments.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


# ---- MLP (digits) ----------------------------------------------------------

MLP_DIMS = (784, 256, 128, 10)


def mlp_init(key):
    keys = jax.random.split(key, len(MLP_DIMS) - 1)
    return {f"fc{i}": _dense_init(k, MLP_DIMS[i], MLP_DIMS[i + 1]) for i, k in enumerate(keys)}


def mlp_apply(params, x):
    h = x.reshape((x.shape[0], -1)).astype(jnp.float32)
    for i in range(len(MLP_DIMS) - 2):
        p = params[f"fc{i}"]
        h = jax.nn.relu(h @ p["w"] + p["b"])
    p = params[f"fc{len(MLP_DIMS) - 2}"]
    return h @ p["w"] + p["b"]


# ---- Two-layer CNN (paper Fig. 1 "MNIST" model) ----------------------------


def cnn_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1": _conv_init(k1, 3, 3, 1, 8),
        "conv2": _conv_init(k2, 3, 3, 8, 16),
        "fc": _dense_init(k3, 7 * 7 * 16, 10),
    }


def cnn_apply(params, x):
    """x: (B, 28, 28, 1) -> logits (B, 10)."""
    h = jax.nn.relu(conv2d(x.astype(jnp.float32), params["conv1"]))
    h = maxpool2(h)                                  # 14x14x8
    h = jax.nn.relu(conv2d(h, params["conv2"]))
    h = maxpool2(h)                                  # 7x7x16
    h = h.reshape((h.shape[0], -1))
    return h @ params["fc"]["w"] + params["fc"]["b"]


# ---- MiniResNet (stand-in for ResNet50 — see DESIGN.md §5) -----------------

RESNET_WIDTH = 16
RESNET_BLOCKS = 3


def resnet_init(key):
    keys = jax.random.split(key, 2 + 2 * RESNET_BLOCKS)
    params = {"stem": _conv_init(keys[0], 3, 3, 3, RESNET_WIDTH)}
    for b in range(RESNET_BLOCKS):
        params[f"block{b}_conv1"] = _conv_init(keys[1 + 2 * b], 3, 3, RESNET_WIDTH, RESNET_WIDTH)
        params[f"block{b}_conv2"] = _conv_init(keys[2 + 2 * b], 3, 3, RESNET_WIDTH, RESNET_WIDTH)
    params["fc"] = _dense_init(keys[-1], RESNET_WIDTH, 10)
    return params


def resnet_apply(params, x):
    """x: (B, 16, 16, 3) -> logits (B, 10). Residual adds after every block
    make the network depth-sensitive to quantization error, the property
    Fig. 1 relies on."""
    h = jax.nn.relu(conv2d(x.astype(jnp.float32), params["stem"]))
    for b in range(RESNET_BLOCKS):
        r = jax.nn.relu(conv2d(h, params[f"block{b}_conv1"]))
        r = conv2d(r, params[f"block{b}_conv2"])
        h = jax.nn.relu(h + r)
    h = h.mean(axis=(1, 2))                          # global average pool
    return h @ params["fc"]["w"] + params["fc"]["b"]


# ---- TinyBert (stand-in for BERT-large — see DESIGN.md §5) -----------------

BERT_VOCAB = 32
BERT_SEQ = 32
BERT_DIM = 64
BERT_HEADS = 4
BERT_FFN = 128
BERT_LAYERS = 2
BERT_CLASSES = 4


def bert_init(key):
    keys = jax.random.split(key, 2 + 6 * BERT_LAYERS)
    params = {
        "embed": (jax.random.normal(keys[0], (BERT_VOCAB, BERT_DIM)) * 0.05).astype(jnp.float32),
        "pos": (jax.random.normal(keys[1], (BERT_SEQ, BERT_DIM)) * 0.05).astype(jnp.float32),
    }
    for l in range(BERT_LAYERS):
        k = keys[2 + 6 * l : 8 + 6 * l]
        params[f"l{l}_wq"] = _dense_init(k[0], BERT_DIM, BERT_DIM)
        params[f"l{l}_wk"] = _dense_init(k[1], BERT_DIM, BERT_DIM)
        params[f"l{l}_wv"] = _dense_init(k[2], BERT_DIM, BERT_DIM)
        params[f"l{l}_wo"] = _dense_init(k[3], BERT_DIM, BERT_DIM)
        params[f"l{l}_ffn1"] = _dense_init(k[4], BERT_DIM, BERT_FFN)
        params[f"l{l}_ffn2"] = _dense_init(k[5], BERT_FFN, BERT_DIM)
        params[f"l{l}_ln1"] = {"g": jnp.ones((BERT_DIM,)), "b": jnp.zeros((BERT_DIM,))}
        params[f"l{l}_ln2"] = {"g": jnp.ones((BERT_DIM,)), "b": jnp.zeros((BERT_DIM,))}
    params["cls"] = _dense_init(jax.random.split(key)[0], BERT_DIM, BERT_CLASSES)
    return params


def _attention(h, params, l):
    b, s, d = h.shape
    hd = d // BERT_HEADS

    def proj(name):
        p = params[f"l{l}_{name}"]
        return (h @ p["w"] + p["b"]).reshape(b, s, BERT_HEADS, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = proj("wq"), proj("wk"), proj("wv")
    att = jax.nn.softmax(qh @ kh.transpose(0, 1, 3, 2) / np.sqrt(hd), axis=-1)
    out = (att @ vh).transpose(0, 2, 1, 3).reshape(b, s, d)
    p = params[f"l{l}_wo"]
    return out @ p["w"] + p["b"]


def bert_apply(params, tokens):
    """tokens: int (B, SEQ) -> logits (B, BERT_CLASSES)."""
    h = params["embed"][tokens] + params["pos"][None, :, :]
    for l in range(BERT_LAYERS):
        h = layernorm(h + _attention(h, params, l), params[f"l{l}_ln1"])
        p1, p2 = params[f"l{l}_ffn1"], params[f"l{l}_ffn2"]
        ffn = gelu(h @ p1["w"] + p1["b"]) @ p2["w"] + p2["b"]
        h = layernorm(h + ffn, params[f"l{l}_ln2"])
    pooled = h.mean(axis=1)
    p = params["cls"]
    return pooled @ p["w"] + p["b"]


MODELS = {
    "mlp": (mlp_init, mlp_apply),
    "cnn": (cnn_init, cnn_apply),
    "resnet": (resnet_init, resnet_apply),
    "bert": (bert_init, bert_apply),
}
