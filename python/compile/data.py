"""Synthetic datasets (offline image: no downloads — see DESIGN.md §5).

Three tasks mirroring the paper's benchmark mix:
  * digits : 28x28x1 procedurally rendered digits (MNIST stand-in) for the
             MLP and the two-layer CNN of Fig. 1.
  * shapes : 16x16x3 colored geometric patterns, 10 classes, for MiniResNet
             (ResNet50/ImageNet stand-in).
  * tokens : length-32 integer sequences, 4-way majority-group
             classification, for TinyBert (BERT-large stand-in).

All generators are deterministic in the seed so the rust side and the
python side can regenerate identical evaluation sets.
"""

from __future__ import annotations

import numpy as np

# 5x7 bitmap font for digits 0-9 (rows of 5 bits, MSB left).
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["01110", "10000", "11110", "10001", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],
}


def _glyph(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _FONT[d]], dtype=np.float32)


def digits_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n,28,28,1) f32 in [0,1], labels (n,) int64)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    imgs = np.zeros((n, 28, 28, 1), dtype=np.float32)
    for i, lab in enumerate(labels):
        g = _glyph(int(lab))
        scale = int(rng.integers(2, 4))  # 2x or 3x upscale
        big = np.kron(g, np.ones((scale, scale), dtype=np.float32))
        # random stroke thickening: OR with a 1-px shifted copy
        if rng.random() < 0.5:
            shifted = np.zeros_like(big)
            shifted[:, 1:] = big[:, :-1]
            big = np.maximum(big, shifted)
        gh, gw = big.shape
        oy = int(rng.integers(0, 28 - gh + 1))
        ox = int(rng.integers(0, 28 - gw + 1))
        canvas = np.zeros((28, 28), dtype=np.float32)
        canvas[oy : oy + gh, ox : ox + gw] = big
        intensity = 0.7 + 0.3 * rng.random()
        canvas *= intensity
        canvas += rng.normal(0, 0.08, canvas.shape).astype(np.float32)
        imgs[i, :, :, 0] = np.clip(canvas, 0.0, 1.0)
    return imgs, labels.astype(np.int64)


def _shape_pattern(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One 16x16 binary pattern for class `cls` in [0, 10)."""
    yy, xx = np.mgrid[0:16, 0:16].astype(np.float32)
    cy, cx = 7.5 + rng.uniform(-1.5, 1.5), 7.5 + rng.uniform(-1.5, 1.5)
    r = 4.0 + rng.uniform(-1.0, 1.5)
    d2 = (yy - cy) ** 2 + (xx - cx) ** 2
    if cls == 0:  # disk
        return (d2 <= r * r).astype(np.float32)
    if cls == 1:  # ring
        return ((d2 <= r * r) & (d2 >= (r - 2) ** 2)).astype(np.float32)
    if cls == 2:  # square
        return ((np.abs(yy - cy) <= r * 0.8) & (np.abs(xx - cx) <= r * 0.8)).astype(np.float32)
    if cls == 3:  # diamond
        return ((np.abs(yy - cy) + np.abs(xx - cx)) <= r).astype(np.float32)
    if cls == 4:  # horizontal stripes
        period = int(rng.integers(3, 5))
        return ((yy.astype(np.int64) // period) % 2 == 0).astype(np.float32)
    if cls == 5:  # vertical stripes
        period = int(rng.integers(3, 5))
        return ((xx.astype(np.int64) // period) % 2 == 0).astype(np.float32)
    if cls == 6:  # checkerboard
        period = int(rng.integers(3, 5))
        return (((yy.astype(np.int64) // period) + (xx.astype(np.int64) // period)) % 2 == 0).astype(np.float32)
    if cls == 7:  # diagonal band
        off = rng.uniform(-3, 3)
        return (np.abs(yy - xx + off) <= 2.5).astype(np.float32)
    if cls == 8:  # cross
        return ((np.abs(yy - cy) <= 1.5) | (np.abs(xx - cx) <= 1.5)).astype(np.float32)
    # cls == 9: corner gradient
    return ((yy + xx) / 30.0).astype(np.float32)


def shapes_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n,16,16,3) f32 in [0,1], labels (n,) int64)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    imgs = np.zeros((n, 16, 16, 3), dtype=np.float32)
    for i, lab in enumerate(labels):
        pat = _shape_pattern(int(lab), rng)
        color = rng.uniform(0.5, 1.0, size=3).astype(np.float32)
        bg = rng.uniform(0.0, 0.25, size=3).astype(np.float32)
        img = pat[:, :, None] * color[None, None, :] + (1 - pat[:, :, None]) * bg[None, None, :]
        img += rng.normal(0, 0.05, img.shape).astype(np.float32)
        imgs[i] = np.clip(img, 0.0, 1.0)
    return imgs, labels.astype(np.int64)


def tokens_dataset(n: int, seed: int, vocab: int = 32, seq: int = 32, classes: int = 4):
    """Majority-group token classification.

    Tokens are split into `classes` groups by `token % classes`; the label
    is the group with the highest count in the sequence (ties -> smallest
    group id).  Requires aggregation over the whole sequence, which
    exercises attention + pooling.
    """
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(n, seq))
    # bias each sequence toward a random group so classes are learnable
    for i in range(n):
        g = int(rng.integers(0, classes))
        mask = rng.random(seq) < 0.35
        group_tokens = np.arange(vocab)[np.arange(vocab) % classes == g]
        toks[i, mask] = rng.choice(group_tokens, size=int(mask.sum()))
    counts = np.zeros((n, classes), dtype=np.int64)
    for g in range(classes):
        counts[:, g] = ((toks % classes) == g).sum(axis=1)
    labels = counts.argmax(axis=1)
    return toks.astype(np.int64), labels.astype(np.int64)


DATASETS = {
    "digits": digits_dataset,
    "shapes": shapes_dataset,
    "tokens": tokens_dataset,
}
