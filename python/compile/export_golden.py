"""Golden cross-check exporter: pins the python and rust implementations of
the RNS substrate to each other.

Writes artifacts/golden.rt (RNSTORE1) containing, for each Table-I bit
width:
  * random signed values + their residues (forward-conversion goldens)
  * CRT reconstruction results (crt goldens)
  * quantization cases: float matrix -> quantized ints + scales
  * RRNS decode cases: corrupted codewords + expected decoded value
    (-2^62 sentinel marks "Detected")

The rust test `integration_golden.rs` loads this file and asserts its own
implementations produce identical results — catching any silent divergence
between python/compile/rnsmath.py and rust/src/rns/.
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from . import tensorstore as TS
from .rnsmath import PAPER_TABLE1, RnsContext, extend_moduli
from .rrns import RrnsCode

DETECTED_SENTINEL = -(2**62)


def export(out_dir: str, seed: int = 20240711, cases: int = 256) -> str:
    rng = np.random.default_rng(seed)
    tensors: dict[str, np.ndarray] = {}
    for bits, moduli in PAPER_TABLE1.items():
        ctx = RnsContext(moduli)
        half = ctx.big_m // 2
        vals = rng.integers(-(half - 1), half, size=cases, dtype=np.int64)
        res = ctx.forward_array(vals)  # (cases, n)
        tensors[f"b{bits}.moduli"] = np.asarray(moduli, dtype=np.int64)
        tensors[f"b{bits}.values"] = vals
        tensors[f"b{bits}.residues"] = res.astype(np.int64)
        # crt goldens: reconstruct from residues (must equal vals)
        rec = ctx.crt_signed_array(res.T)
        assert np.array_equal(rec, vals)
        tensors[f"b{bits}.crt"] = rec

    # quantization goldens (b = 8): matrix + expected q + scales
    from . import quantize as q
    import jax.numpy as jnp

    x = rng.normal(0, 2, size=(8, 32)).astype(np.float32)
    xq, s = q.quantize_activations(jnp.asarray(x), 8)
    tensors["quant.x"] = x
    tensors["quant.xq"] = np.asarray(xq).astype(np.int64)
    tensors["quant.scales"] = np.asarray(s).reshape(-1).astype(np.float32)

    # RRNS decode goldens (b = 8 + 2 redundant)
    all_moduli = extend_moduli(PAPER_TABLE1[8], 2)
    code = RrnsCode(all_moduli, len(PAPER_TABLE1[8]))
    half = code.legitimate_range // 2
    words = []
    expected = []
    for _ in range(cases):
        v = int(rng.integers(-(half - 1), half))
        res = code.encode(v)
        n_err = int(rng.integers(0, 3))  # 0, 1 or 2 errors
        idxs = rng.choice(code.n, size=n_err, replace=False)
        for i in idxs:
            m = all_moduli[i]
            res[i] = int((res[i] + 1 + rng.integers(0, m - 1)) % m)
        out = code.decode(res)
        words.append(res)
        expected.append(DETECTED_SENTINEL if out is None else out[0])
    tensors["rrns.moduli"] = np.asarray(all_moduli, dtype=np.int64)
    tensors["rrns.k"] = np.asarray([code.k], dtype=np.int64)
    tensors["rrns.words"] = np.asarray(words, dtype=np.int64)
    tensors["rrns.expected"] = np.asarray(expected, dtype=np.int64)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "golden.rt")
    TS.save(path, tensors)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    print(f"wrote {export(args.out)}")


if __name__ == "__main__":
    main()
