"""RNS math shared by the kernels, the L2 model, and the tests.

Everything here is plain python / numpy over exact integers; it mirrors the
rust `rns` crate module (rust/src/rns/) and the two are cross-checked by the
golden files exported at artifact-build time.

Paper mapping (Demirkiran et al., 2023):
  - moduli selection     -> Table I ("minimum number of moduli that
    guarantees Eq. (4) for h while keeping the moduli under bit width b")
  - forward conversion   -> Eq. (3) inner `|.|_M` operations
  - CRT reconstruction   -> Eq. (1)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


def gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def egcd(a: int, b: int) -> tuple[int, int, int]:
    """Extended euclid: returns (g, x, y) with a*x + b*y = g."""
    if b == 0:
        return a, 1, 0
    g, x, y = egcd(b, a % b)
    return g, y, x - (a // b) * y


def mod_inverse(a: int, m: int) -> int:
    g, x, _ = egcd(a % m, m)
    if g != 1:
        raise ValueError(f"{a} has no inverse mod {m}")
    return x % m


def pairwise_coprime(moduli: list[int]) -> bool:
    for i in range(len(moduli)):
        for j in range(i + 1, len(moduli)):
            if gcd(moduli[i], moduli[j]) != 1:
                return False
    return True


def required_output_bits(b_in: int, b_w: int, h: int) -> int:
    """Eq. (4): b_out = b_in + b_w + log2(h) - 1 for an h-element dot product."""
    return b_in + b_w + int(math.ceil(math.log2(h))) - 1


def _best_coprime_subset(cands: list[int], n: int) -> tuple[int, list[int]]:
    """Max-product pairwise-coprime subset of size n (branch and bound).

    `cands` must be sorted descending.  Returns (product, subset)."""
    best_prod = 0
    best: list[int] = []

    def dfs(start: int, chosen: list[int], prod: int) -> None:
        nonlocal best_prod, best
        if len(chosen) == n:
            if prod > best_prod:
                best_prod, best = prod, list(chosen)
            return
        remaining = n - len(chosen)
        for i in range(start, len(cands) - remaining + 1):
            c = cands[i]
            # upper bound: fill remaining slots with copies of c
            if prod * (c**remaining) <= best_prod:
                return  # cands are descending: no later branch can beat best
            if all(gcd(c, x) == 1 for x in chosen):
                chosen.append(c)
                dfs(i + 1, chosen, prod * c)
                chosen.pop()

    dfs(0, [], 1)
    return best_prod, best


def select_moduli(bits: int, h: int) -> list[int]:
    """Table-I moduli selection: minimal number of moduli n such that a
    pairwise-coprime set below 2^bits covers Eq. (4), choosing the
    max-product set for that n (ties in the paper resolve the same way).

    Reproduces the paper's example sets for h = 128:
      b=4 -> {15, 14, 13, 11}      b=5 -> {31, 29, 28, 27}
      b=6 -> {63, 62, 61, 59}      b=7 -> {127, 126, 125}
      b=8 -> {255, 254, 253}
    """
    b_out = required_output_bits(bits, bits, h)
    target = 1 << b_out
    cands = list(range((1 << bits) - 1, 1, -1))
    for n in range(1, 16):
        prod, subset = _best_coprime_subset(cands, n)
        if prod >= target:
            return subset
    raise ValueError(f"cannot cover {b_out} bits with {bits}-bit moduli")


def extend_moduli(moduli: list[int], extra: int) -> list[int]:
    """Append `extra` redundant moduli (next largest coprime values below the
    smallest existing modulus) for RRNS(n, k) with n = k + extra."""
    out = list(moduli)
    cand = min(moduli) - 1
    for _ in range(extra):
        while cand >= 2 and not all(gcd(cand, x) == 1 for x in out):
            cand -= 1
        if cand < 2:
            raise ValueError("ran out of coprime candidates for redundancy")
        out.append(cand)
        cand -= 1
    return out


@dataclass
class RnsContext:
    """Precomputed CRT constants for one moduli set (paper Eq. (1))."""

    moduli: list[int]
    big_m: int = field(init=False)
    m_i: list[int] = field(init=False)       # M_i = M / m_i
    t_i: list[int] = field(init=False)       # T_i = (M_i)^-1 mod m_i
    crt_coeff: list[int] = field(init=False)  # |M_i * T_i|_M

    def __post_init__(self) -> None:
        if not pairwise_coprime(self.moduli):
            raise ValueError(f"moduli {self.moduli} are not pairwise coprime")
        self.big_m = math.prod(self.moduli)
        self.m_i = [self.big_m // m for m in self.moduli]
        self.t_i = [mod_inverse(mi, m) for mi, m in zip(self.m_i, self.moduli)]
        self.crt_coeff = [(mi * ti) % self.big_m for mi, ti in zip(self.m_i, self.t_i)]

    @property
    def n(self) -> int:
        return len(self.moduli)

    def forward(self, a: int) -> list[int]:
        """Signed integer -> residues. Negative values map to M - |a| (mod M)."""
        return [a % m for m in self.moduli]

    def forward_array(self, a: np.ndarray) -> np.ndarray:
        """Vectorized forward conversion -> int64 array [..., n]."""
        a = np.asarray(a, dtype=np.int64)
        mods = np.array(self.moduli, dtype=np.int64)
        return np.mod(a[..., None], mods)

    def crt(self, residues: list[int]) -> int:
        """Eq. (1): unsigned reconstruction in [0, M)."""
        acc = 0
        for r, c in zip(residues, self.crt_coeff):
            acc = (acc + (r % self.big_m) * c) % self.big_m
        return acc

    def crt_signed(self, residues: list[int]) -> int:
        """Reconstruction into the symmetric range (-M/2, M/2]."""
        v = self.crt(residues)
        return v - self.big_m if v > self.big_m // 2 else v

    def crt_signed_array(self, residues: np.ndarray) -> np.ndarray:
        """Vectorized signed CRT. residues: int64 [n, ...] -> int64 [...].

        Uses python-object arithmetic when M^2 might overflow int64; with the
        paper's moduli (M < 2^25) everything fits comfortably in int64.
        """
        residues = np.asarray(residues, dtype=np.int64)
        coeff = np.array(self.crt_coeff, dtype=np.int64)
        acc = np.zeros(residues.shape[1:], dtype=np.int64)
        for i in range(self.n):
            acc = (acc + residues[i] * coeff[i]) % self.big_m
        return np.where(acc > self.big_m // 2, acc - self.big_m, acc)


# The exact Table-I sets from the paper, used as golden values in tests.
PAPER_TABLE1 = {
    4: [15, 14, 13, 11],
    5: [31, 29, 28, 27],
    6: [63, 62, 61, 59],
    7: [127, 126, 125],
    8: [255, 254, 253],
}
