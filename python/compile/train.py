"""Build-time training of the evaluation model zoo (hand-rolled Adam).

Runs once under `make artifacts`; exports trained weights + frozen eval
sets in the RNSTORE1 format that the rust nn substrate loads.  Python never
runs at serving time — these artifacts are the only hand-off.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import tensorstore as TS

EVAL_N = 512
TRAIN_SEED = 1234
EVAL_SEED = 999


def flatten_params(params, prefix: str = "") -> dict[str, np.ndarray]:
    flat: dict[str, np.ndarray] = {}
    if isinstance(params, dict):
        for k, v in params.items():
            flat.update(flatten_params(v, f"{prefix}{k}." if prefix or True else k))
    else:
        flat[prefix[:-1]] = np.asarray(params, dtype=np.float32)
    return flat


def unflatten_params(flat: dict[str, np.ndarray]):
    tree: dict = {}
    for name, arr in flat.items():
        parts = name.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)
    return tree


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new, {"m": m, "v": v, "t": t}


TASKS = {
    # model -> (dataset, train_n, steps, batch)
    "mlp": ("digits", 8192, 400, 64),
    "cnn": ("digits", 8192, 400, 64),
    "resnet": ("shapes", 8192, 600, 64),
    "bert": ("tokens", 8192, 600, 64),
}


def train_model(name: str, verbose: bool = True):
    dataset, train_n, steps, batch = TASKS[name]
    init_fn, apply_fn = M.MODELS[name]
    xs, ys = D.DATASETS[dataset](train_n, TRAIN_SEED)
    params = init_fn(jax.random.PRNGKey(42))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, bx, by):
        def loss_fn(p):
            return cross_entropy(apply_fn(p, bx), by)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_step(params, grads, opt)
        return params, opt, loss

    rng = np.random.default_rng(7)
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, train_n, size=batch)
        bx = jnp.asarray(xs[idx])
        by = jnp.asarray(ys[idx])
        params, opt, loss = step(params, opt, bx, by)
        if verbose and (s % 100 == 0 or s == steps - 1):
            print(f"  [{name}] step {s:4d} loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return params


def eval_accuracy(name: str, params, xs, ys) -> float:
    _, apply_fn = M.MODELS[name]
    preds = np.asarray(jnp.argmax(apply_fn(params, jnp.asarray(xs)), axis=-1))
    return float((preds == ys).mean())


def export_all(out_dir: str, models: list[str] | None = None) -> dict[str, float]:
    os.makedirs(os.path.join(out_dir, "models"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    accs: dict[str, float] = {}
    exported_sets: set[str] = set()
    for name in models or list(TASKS):
        dataset = TASKS[name][0]
        exs, eys = D.DATASETS[dataset](EVAL_N, EVAL_SEED)
        if dataset not in exported_sets:
            dt = {"x": exs.astype(np.float32) if exs.dtype != np.int64 else exs, "y": eys}
            TS.save(os.path.join(out_dir, "data", f"{dataset}_eval.rt"), dt)
            exported_sets.add(dataset)
        params = train_model(name)
        acc = eval_accuracy(name, params, exs, eys)
        accs[name] = acc
        flat = flatten_params(params)
        flat["__fp32_eval_acc"] = np.array([acc], dtype=np.float32)
        TS.save(os.path.join(out_dir, "models", f"{name}.rt"), flat)
        print(f"  [{name}] fp32 eval accuracy = {acc:.4f}")
    return accs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()
    export_all(args.out, args.models)


if __name__ == "__main__":
    main()
