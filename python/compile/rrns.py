"""Redundant RNS (paper §IV) — python mirror of rust/src/rns/rrns.rs.

Used for (a) python-side unit tests of the coding theory, and (b) the
golden cross-check files (`export_golden.py`) that pin the rust and python
implementations to each other: both decoders must agree on every exported
(codeword, corruption) case.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from itertools import combinations

from .rnsmath import RnsContext, pairwise_coprime


@dataclass
class RrnsCode:
    """RRNS(n, k) with consistency-threshold (maximum-likelihood) decoding.

    Decode contract (mirrors rust): try each k-group CRT candidate within
    the legitimate range; accept the first whose residue disagreements
    number <= t = (n-k)//2.  Returns (value, suspects) or None (detected).
    """

    moduli: list[int]
    k: int
    full: RnsContext = field(init=False)
    groups: list[tuple[int, ...]] = field(init=False)
    group_ctxs: list[RnsContext] = field(init=False)
    legitimate_range: int = field(init=False)

    def __post_init__(self) -> None:
        n = len(self.moduli)
        if not (0 < self.k <= n):
            raise ValueError(f"invalid k={self.k} for n={n}")
        if not pairwise_coprime(self.moduli):
            raise ValueError("moduli not pairwise coprime")
        self.full = RnsContext(self.moduli)
        self.groups = list(combinations(range(n), self.k))
        self.group_ctxs = [RnsContext([self.moduli[i] for i in g]) for g in self.groups]
        self.legitimate_range = min(ctx.big_m for ctx in self.group_ctxs)

    @property
    def n(self) -> int:
        return len(self.moduli)

    @property
    def correctable(self) -> int:
        return (self.n - self.k) // 2

    def encode(self, a: int) -> list[int]:
        assert abs(a) <= self.legitimate_range // 2
        return self.full.forward(a)

    def decode(self, residues: list[int]) -> tuple[int, list[int]] | None:
        t = self.correctable
        half = self.legitimate_range // 2
        seen: set[int] = set()
        for g, ctx in zip(self.groups, self.group_ctxs):
            v = ctx.crt_signed([residues[i] for i in g])
            if v > half or v < -(half - 1) or v in seen:
                continue
            seen.add(v)
            suspects = [i for i, m in enumerate(self.moduli) if residues[i] != v % m]
            if len(suspects) <= t:
                return v, suspects
        return None

    def decode_best_effort(self, residues: list[int]) -> int:
        """Most-consistent candidate (mirror of rust decode_best_effort)."""
        half = self.legitimate_range // 2
        best_v, best_c = 0, -1
        for g, ctx in zip(self.groups, self.group_ctxs):
            v = ctx.crt_signed([residues[i] for i in g])
            if v > half or v < -(half - 1):
                continue
            c = sum(1 for i, m in enumerate(self.moduli) if residues[i] == v % m)
            if c > best_c:
                best_c, best_v = c, v
        return best_v
