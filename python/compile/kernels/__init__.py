from . import ref, rns_matmul  # noqa: F401
