"""Pure reference oracles for the L1 kernels.

Two tiers:
  * numpy int64 — the ground truth (exact integer arithmetic, no float).
  * pure-jnp    — a jit-able float reference used for HLO-size comparisons
                  and as the paper's "FP32 ground truth" when measuring
                  dot-product error (Fig. 3).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def modular_matmul_ref(
    x_res: np.ndarray,  # (n, B, K) integer residues
    w_res: np.ndarray,  # (n, K, N)
    moduli: np.ndarray,  # (n,)
) -> np.ndarray:  # (n, B, N) int64
    """Exact per-channel (X_i @ W_i) mod m_i in int64."""
    x = np.asarray(x_res, dtype=np.int64)
    w = np.asarray(w_res, dtype=np.int64)
    out = np.empty((x.shape[0], x.shape[1], w.shape[2]), dtype=np.int64)
    for i, m in enumerate(np.asarray(moduli, dtype=np.int64)):
        out[i] = (x[i] @ w[i]) % m
    return out


def fixed_point_matmul_ref(
    x: np.ndarray,  # (B, K) integer-valued
    w: np.ndarray,  # (K, N)
    dropped_bits: int,
) -> np.ndarray:
    """Exact MVM then symmetric truncation of `dropped_bits` LSBs."""
    y = np.asarray(x, dtype=np.int64) @ np.asarray(w, dtype=np.int64)
    scale = np.int64(1) << np.int64(dropped_bits)
    trunc = np.sign(y) * (np.abs(y) // scale)
    return trunc * scale


def matmul_fp32_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """The paper's FP32 ground truth for error measurements."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
