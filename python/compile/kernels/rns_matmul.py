"""L1 — Pallas kernel for the RNS modular matmul (the paper's hot spot).

Each residue channel i computes  out_i = (X_i @ W_i) mod m_i  where X_i and
W_i hold the residues of the quantized activations/weights w.r.t. modulus
m_i.  This is the digital twin of the paper's per-modulus analog MVM unit
(Fig. 2): the per-block `mod m_i` folded into the accumulation loop plays
the role of the analog-domain modulo (ring oscillator / optical phase) that
keeps the output inside [0, m_i) so a b-bit ADC loses no information.

Hardware adaptation (see DESIGN.md §3): the paper tiles DNN layers onto a
fixed h×h analog array; here the BlockSpec tiles the same computation for
VMEM — one (block_b, block_k)x(block_k, block_n) MXU-shaped tile per grid
step, channel-major grid so the n residue channels stay independent
(no carry propagation, exactly as in the RNS).

Exactness: residues < 2^8 so products < 2^16 and a K-block of <=256
products sums below 2^24 — the exact-integer range of f32.  Reducing
`mod m` after every block keeps every intermediate exactly representable,
making this f32 kernel bit-identical to the int64 oracle in ref.py.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both the python tests
and the rust runtime can run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Maximum K-block that keeps a block-sum of 8-bit residue products below
# 2^24 (f32 exact-integer range): 255^2 * 256 = 16.6M < 2^24? No: 2^24 =
# 16.78M and 255^2*256 = 16.65M — inside, but without headroom for the
# carried accumulator (< m <= 255).  128 gives 2x headroom; it also matches
# the paper's h=128 analog array height.
MAX_KBLOCK = 128


def exact_mod(x: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """`x mod m` for non-negative integer-valued f32 x < 2^24.

    f32 division can round the quotient across a multiple-of-m boundary, so
    floor(x/m) may be off by one in either direction; one correction step
    each way restores the exact remainder.
    """
    q = jnp.floor(x / m)
    r = x - q * m
    r = jnp.where(r >= m, r - m, r)
    r = jnp.where(r < 0, r + m, r)
    return r


def _rns_matmul_kernel(m_ref, x_ref, w_ref, o_ref, *, kblock: int):
    """Grid = (n_channels,). Refs carry a leading channel dim of size 1.

    x_ref: (1, B, K) residues of the activations for this channel
    w_ref: (1, K, N) residues of the weights for this channel
    m_ref: (1,)      the channel's modulus (f32-encoded integer)
    o_ref: (1, B, N) output residues in [0, m)
    """
    m = m_ref[0]
    x = x_ref[0]
    w = w_ref[0]
    k_total = x.shape[1]
    nblocks = k_total // kblock

    def body(j, acc):
        xb = lax.dynamic_slice_in_dim(x, j * kblock, kblock, axis=1)
        wb = lax.dynamic_slice_in_dim(w, j * kblock, kblock, axis=0)
        # block partial sums < kblock * (m-1)^2 <= 2^23; acc < m adds < 2^8.
        return exact_mod(acc + jnp.dot(xb, wb), m)

    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    acc = lax.fori_loop(0, nblocks, body, acc)
    rem = k_total - nblocks * kblock
    if rem:  # static tail (shapes are static at trace time)
        xb = lax.dynamic_slice_in_dim(x, nblocks * kblock, rem, axis=1)
        wb = lax.dynamic_slice_in_dim(w, nblocks * kblock, rem, axis=0)
        acc = exact_mod(acc + jnp.dot(xb, wb), m)
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("kblock",))
def rns_matmul(
    x_res: jnp.ndarray,  # f32 (n, B, K), integer-valued residues
    w_res: jnp.ndarray,  # f32 (n, K, N)
    moduli: jnp.ndarray,  # f32 (n,)
    kblock: int = MAX_KBLOCK,
) -> jnp.ndarray:  # f32 (n, B, N)
    """Channel-parallel modular matmul via pallas (interpret mode)."""
    n, b, k = x_res.shape
    _, _, nn = w_res.shape
    if kblock > MAX_KBLOCK:
        raise ValueError(f"kblock {kblock} > MAX_KBLOCK {MAX_KBLOCK} breaks f32 exactness")
    return pl.pallas_call(
        functools.partial(_rns_matmul_kernel, kblock=min(kblock, k) or 1),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, b, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, nn), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, nn), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b, nn), jnp.float32),
        interpret=True,
    )(moduli, x_res, w_res)


def _fixed_point_kernel(x_ref, w_ref, o_ref, *, shift: float, kblock: int):
    """Baseline fixed-point analog MVM with ADC truncation (MSB-keep).

    Computes y = X @ W exactly, then models a b_adc-bit ADC reading only the
    MSBs: out = floor(y / 2^shift) (sign-symmetric, toward zero, matching
    how a truncated two's-complement readout drops LSBs of |y|).
    """
    x = x_ref[...]
    w = w_ref[...]
    k_total = x.shape[1]
    nblocks = (k_total + kblock - 1) // kblock

    def body(j, acc):
        xb = lax.dynamic_slice_in_dim(x, j * kblock, kblock, axis=1)
        wb = lax.dynamic_slice_in_dim(w, j * kblock, kblock, axis=0)
        return acc + jnp.dot(xb, wb)

    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    acc = lax.fori_loop(0, nblocks, body, acc) if k_total % kblock == 0 else x @ w
    scale = 2.0**shift
    trunc = jnp.sign(acc) * jnp.floor(jnp.abs(acc) / scale)
    o_ref[...] = trunc * scale


@functools.partial(jax.jit, static_argnames=("dropped_bits", "kblock"))
def fixed_point_matmul(
    x: jnp.ndarray,  # f32 (B, K) integer-valued quantized activations
    w: jnp.ndarray,  # f32 (K, N) integer-valued quantized weights
    dropped_bits: int,
    kblock: int = MAX_KBLOCK,
) -> jnp.ndarray:
    """Regular fixed-point analog core: exact MVM then drop b_out - b_adc LSBs.

    NOTE exactness: the *untruncated* accumulator can exceed 2^24 for b=8,
    K=128 (b_out = 22).  2^22 < 2^24, so f32 stays exact for every Table-I
    configuration (b<=8, h<=128 -> b_out <= 22); guarded in tests.
    """
    b, k = x.shape
    _, n = w.shape
    return pl.pallas_call(
        functools.partial(
            _fixed_point_kernel, shift=float(dropped_bits), kblock=min(kblock, k) or 1
        ),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w)


# ---------------------------------------------------------------------------
# Grid-accumulation variant: K-blocks as a grid dimension
# ---------------------------------------------------------------------------
#
# `rns_matmul` holds a whole (B, K) x (K, N) channel tile in VMEM and loops
# over K-blocks *inside* the kernel.  For K larger than VMEM allows, the
# canonical TPU pattern instead makes the K-block a grid dimension and
# lets the BlockSpec index_map stream one (B, kblock) x (kblock, N) pair
# per step while the output block stays resident and accumulates — the
# explicit HBM<->VMEM schedule the paper expresses with its h-tall analog
# array.  Both variants are bit-exact against ref.py; aot.py exports the
# first (smaller HLO), and the tests pin them to each other.


def _rns_matmul_grid_kernel(m_ref, x_ref, w_ref, o_ref):
    """Grid = (n_channels, K // kblock); o_ref revisited across dim 1."""
    k_idx = pl.program_id(1)
    m = m_ref[0]

    @pl.when(k_idx == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    acc = o_ref[0] + jnp.dot(x_ref[0], w_ref[0])
    o_ref[0] = exact_mod(acc, m)


@functools.partial(jax.jit, static_argnames=("kblock",))
def rns_matmul_grid(
    x_res: jnp.ndarray,  # f32 (n, B, K)
    w_res: jnp.ndarray,  # f32 (n, K, N)
    moduli: jnp.ndarray,  # f32 (n,)
    kblock: int = MAX_KBLOCK,
) -> jnp.ndarray:
    """K-streamed modular matmul: one (kblock) slab in VMEM per grid step."""
    n, b, k = x_res.shape
    _, _, nn = w_res.shape
    kblock = min(kblock, k)
    if kblock > MAX_KBLOCK:
        raise ValueError(f"kblock {kblock} > MAX_KBLOCK {MAX_KBLOCK} breaks f32 exactness")
    if k % kblock != 0:
        # pad K with zero residues (exact: zero rows contribute nothing)
        pad = kblock - (k % kblock)
        x_res = jnp.pad(x_res, ((0, 0), (0, 0), (0, pad)))
        w_res = jnp.pad(w_res, ((0, 0), (0, pad), (0, 0)))
        k += pad
    return pl.pallas_call(
        _rns_matmul_grid_kernel,
        grid=(n, k // kblock),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, b, kblock), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, kblock, nn), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, nn), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b, nn), jnp.float32),
        interpret=True,
    )(moduli, x_res, w_res)
