"""Build-time python package: L1 pallas kernels + L2 jax models + AOT export.

Nothing in here runs at serving time — `make artifacts` lowers the jitted
entry points to HLO text and trains/exports the small evaluation models;
the rust coordinator consumes only the files under artifacts/.
"""
