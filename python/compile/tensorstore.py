"""Tiny binary tensor container shared with rust (`rust/src/nn/store.rs`).

Format "RNSTORE1" (all little-endian):
    magic   : 8 bytes b"RNSTORE1"
    count   : u32
    per tensor:
        name_len : u32, name bytes (utf-8)
        dtype    : u8  (0 = f32, 1 = i64, 2 = u8)
        ndim     : u32
        dims     : ndim x u32
        data     : product(dims) elements, native width, little-endian
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"RNSTORE1"
_DTYPES = {0: np.float32, 1: np.int64, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int64): 1, np.dtype(np.uint8): 2}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES[code]).newbyteorder("<")
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims)
            out[name] = arr.astype(_DTYPES[code])
    return out
