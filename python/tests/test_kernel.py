"""L1 correctness: the pallas kernels vs the exact int64 oracles.

This is the CORE correctness signal for the compute hot path — hypothesis
sweeps shapes and bit-widths and requires *bit-exact* agreement.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.rns_matmul import MAX_KBLOCK, exact_mod, fixed_point_matmul, rns_matmul
from compile.rnsmath import PAPER_TABLE1, RnsContext, required_output_bits


def _residues(ctx, arr):
    """int array (..., ) -> f32 residue channels (n, ...)."""
    r = ctx.forward_array(arr)
    return np.moveaxis(r, -1, 0).astype(np.float32)


class TestExactMod:
    @given(st.integers(0, (1 << 24) - 1), st.integers(2, 255))
    @settings(max_examples=200, deadline=None)
    def test_matches_integer_mod(self, x, m):
        got = float(exact_mod(jnp.float32(x), jnp.float32(m)))
        assert got == x % m

    def test_boundary_multiples(self):
        # exact multiples of m are the rounding hazard for floor(x/m)
        for m in (3, 59, 127, 255):
            for k in (1, 2, 1000, 65535):
                if k * m < (1 << 24):
                    assert float(exact_mod(jnp.float32(k * m), jnp.float32(m))) == 0.0


class TestRnsMatmulKernel:
    @pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
    def test_bit_exact_vs_oracle_table1(self, bits):
        ctx = RnsContext(PAPER_TABLE1[bits])
        rng = np.random.default_rng(bits)
        qm = (1 << (bits - 1)) - 1
        x = rng.integers(-qm, qm + 1, (4, 128))
        w = rng.integers(-qm, qm + 1, (128, 64))
        xr, wr = _residues(ctx, x), _residues(ctx, w)
        mods = np.asarray(ctx.moduli, np.float32)
        out = np.asarray(rns_matmul(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods)))
        oracle = ref.modular_matmul_ref(xr, wr, ctx.moduli)
        assert np.array_equal(out.astype(np.int64), oracle)

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_shape_sweep(self, data):
        bits = data.draw(st.sampled_from([4, 6, 8]))
        b = data.draw(st.integers(1, 5))
        k = data.draw(st.sampled_from([1, 3, 8, 33, 128, 200, 256]))
        n_out = data.draw(st.sampled_from([1, 7, 32]))
        kblock = data.draw(st.sampled_from([16, 100, MAX_KBLOCK]))
        ctx = RnsContext(PAPER_TABLE1[bits])
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        qm = (1 << (bits - 1)) - 1
        x = rng.integers(-qm, qm + 1, (b, k))
        w = rng.integers(-qm, qm + 1, (k, n_out))
        xr, wr = _residues(ctx, x), _residues(ctx, w)
        mods = np.asarray(ctx.moduli, np.float32)
        out = np.asarray(
            rns_matmul(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods), kblock=kblock)
        )
        assert np.array_equal(out.astype(np.int64), ref.modular_matmul_ref(xr, wr, ctx.moduli))

    def test_crt_recovers_exact_dot_product(self):
        """End-to-end: kernel residues + CRT == exact integer matmul (the
        paper's 'no information loss' claim, §III-B)."""
        ctx = RnsContext(PAPER_TABLE1[6])
        rng = np.random.default_rng(0)
        x = rng.integers(-31, 32, (8, 128))
        w = rng.integers(-31, 32, (128, 128))
        xr, wr = _residues(ctx, x), _residues(ctx, w)
        mods = np.asarray(ctx.moduli, np.float32)
        out = np.asarray(rns_matmul(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods)))
        rec = ctx.crt_signed_array(out.astype(np.int64))
        assert np.array_equal(rec, x.astype(np.int64) @ w.astype(np.int64))

    def test_kblock_guard(self):
        ctx = RnsContext(PAPER_TABLE1[4])
        xr = jnp.zeros((4, 1, 8), jnp.float32)
        wr = jnp.zeros((4, 8, 1), jnp.float32)
        with pytest.raises(ValueError):
            rns_matmul(xr, wr, jnp.asarray(ctx.moduli, jnp.float32), kblock=MAX_KBLOCK * 4)

    def test_zero_inputs(self):
        ctx = RnsContext(PAPER_TABLE1[6])
        xr = jnp.zeros((4, 2, 16), jnp.float32)
        wr = jnp.zeros((4, 16, 3), jnp.float32)
        out = rns_matmul(xr, wr, jnp.asarray(ctx.moduli, jnp.float32))
        assert np.all(np.asarray(out) == 0)


class TestFixedPointKernel:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_truncation_matches_oracle(self, bits):
        rng = np.random.default_rng(bits)
        qm = (1 << (bits - 1)) - 1
        x = rng.integers(-qm, qm + 1, (4, 128))
        w = rng.integers(-qm, qm + 1, (128, 32))
        dropped = required_output_bits(bits, bits, 128) - bits
        out = np.asarray(
            fixed_point_matmul(jnp.asarray(x, jnp.float32), jnp.asarray(w, jnp.float32), dropped)
        )
        assert np.array_equal(out.astype(np.int64), ref.fixed_point_matmul_ref(x, w, dropped))

    def test_zero_dropped_bits_is_exact(self):
        rng = np.random.default_rng(1)
        x = rng.integers(-7, 8, (2, 16))
        w = rng.integers(-7, 8, (16, 4))
        out = np.asarray(fixed_point_matmul(jnp.asarray(x, jnp.float32), jnp.asarray(w, jnp.float32), 0))
        assert np.array_equal(out.astype(np.int64), x.astype(np.int64) @ w.astype(np.int64))

    def test_truncation_loses_information(self):
        """Sanity: with the Table-I number of dropped bits the baseline's
        error is nonzero (the loss the RNS core eliminates)."""
        rng = np.random.default_rng(2)
        x = rng.integers(-127, 128, (8, 128))
        w = rng.integers(-127, 128, (128, 8))
        dropped = required_output_bits(8, 8, 128) - 8  # 14 bits
        out = np.asarray(
            fixed_point_matmul(jnp.asarray(x, jnp.float32), jnp.asarray(w, jnp.float32), dropped)
        )
        exact = x.astype(np.int64) @ w.astype(np.int64)
        assert not np.array_equal(out.astype(np.int64), exact)
        # but the kept MSBs are consistent: |err| < 2^dropped
        assert np.abs(out - exact).max() < (1 << dropped)


class TestGridVariant:
    """The K-streamed grid-accumulation kernel must match both the in-kernel
    loop variant and the int64 oracle bit-for-bit."""

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_bit_exact_vs_oracle(self, bits):
        from compile.kernels.rns_matmul import rns_matmul_grid

        ctx = RnsContext(PAPER_TABLE1[bits])
        rng = np.random.default_rng(100 + bits)
        qm = (1 << (bits - 1)) - 1
        x = rng.integers(-qm, qm + 1, (3, 256))
        w = rng.integers(-qm, qm + 1, (256, 32))
        xr, wr = _residues(ctx, x), _residues(ctx, w)
        mods = np.asarray(ctx.moduli, np.float32)
        out = np.asarray(
            rns_matmul_grid(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods), kblock=64)
        )
        assert np.array_equal(out.astype(np.int64), ref.modular_matmul_ref(xr, wr, ctx.moduli))

    @given(data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_agrees_with_loop_variant(self, data):
        from compile.kernels.rns_matmul import rns_matmul_grid

        bits = data.draw(st.sampled_from([4, 8]))
        k = data.draw(st.sampled_from([1, 16, 100, 128, 192, 256]))
        kblock = data.draw(st.sampled_from([16, 64, 128]))
        b = data.draw(st.integers(1, 4))
        ctx = RnsContext(PAPER_TABLE1[bits])
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        qm = (1 << (bits - 1)) - 1
        x = rng.integers(-qm, qm + 1, (b, k))
        w = rng.integers(-qm, qm + 1, (k, 8))
        xr, wr = _residues(ctx, x), _residues(ctx, w)
        mods = np.asarray(ctx.moduli, np.float32)
        a = np.asarray(rns_matmul(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods)))
        g = np.asarray(
            rns_matmul_grid(jnp.asarray(xr), jnp.asarray(wr), jnp.asarray(mods), kblock=kblock)
        )
        assert np.array_equal(a, g)

    def test_kblock_guard(self):
        from compile.kernels.rns_matmul import MAX_KBLOCK, rns_matmul_grid

        ctx = RnsContext(PAPER_TABLE1[4])
        xr = jnp.zeros((4, 1, 512), jnp.float32)
        wr = jnp.zeros((4, 512, 1), jnp.float32)
        with pytest.raises(ValueError):
            rns_matmul_grid(xr, wr, jnp.asarray(ctx.moduli, jnp.float32), kblock=512)
