"""Datasets (determinism, learnability) + tensorstore round-trip + training
machinery smoke tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import tensorstore as TS
from compile import train as T
from compile import model as M


class TestDatasets:
    @pytest.mark.parametrize("name", ["digits", "shapes", "tokens"])
    def test_deterministic_in_seed(self, name):
        a = D.DATASETS[name](32, 5)
        b = D.DATASETS[name](32, 5)
        c = D.DATASETS[name](32, 6)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        assert not np.array_equal(a[0], c[0])

    def test_digits_shapes_ranges(self):
        x, y = D.digits_dataset(64, 0)
        assert x.shape == (64, 28, 28, 1) and x.min() >= 0 and x.max() <= 1
        assert set(np.unique(y)).issubset(set(range(10)))

    def test_shapes_shapes(self):
        x, y = D.shapes_dataset(64, 0)
        assert x.shape == (64, 16, 16, 3)
        assert y.min() >= 0 and y.max() < 10

    def test_tokens_label_rule(self):
        x, y = D.tokens_dataset(128, 0)
        counts = np.stack([((x % 4) == g).sum(axis=1) for g in range(4)], axis=1)
        assert np.array_equal(y, counts.argmax(axis=1))

    def test_all_classes_present(self):
        for name in ("digits", "shapes", "tokens"):
            _, y = D.DATASETS[name](512, 1)
            assert len(np.unique(y)) >= 4


class TestTensorStore:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "a.w": rng.normal(size=(3, 4)).astype(np.float32),
            "labels": rng.integers(0, 10, size=(7,)).astype(np.int64),
            "bytes": rng.integers(0, 255, size=(2, 2, 2)).astype(np.uint8),
            "scalarish": np.array([1.5], dtype=np.float32),
        }
        p = os.path.join(tmp_path, "t.rt")
        TS.save(p, tensors)
        back = TS.load(p)
        assert set(back) == set(tensors)
        for k in tensors:
            assert back[k].dtype == tensors[k].dtype
            assert np.array_equal(back[k], tensors[k])

    def test_bad_magic(self, tmp_path):
        p = os.path.join(tmp_path, "bad.rt")
        with open(p, "wb") as f:
            f.write(b"NOTMAGIC" + b"\x00" * 16)
        with pytest.raises(ValueError):
            TS.load(p)

    def test_rejects_unsupported_dtype(self, tmp_path):
        with pytest.raises(TypeError):
            TS.save(os.path.join(tmp_path, "x.rt"), {"a": np.zeros(3, np.complex64)})


class TestTraining:
    def test_flatten_unflatten_roundtrip(self):
        params = M.mlp_init(jax.random.PRNGKey(0))
        flat = T.flatten_params(params)
        assert "fc0.w" in flat
        tree = T.unflatten_params(flat)
        for k in params:
            assert np.array_equal(np.asarray(params[k]["w"]), np.asarray(tree[k]["w"]))

    def test_adam_decreases_loss(self):
        """A few Adam steps on the MLP reduce the training loss."""
        xs, ys = D.digits_dataset(256, 0)
        params = M.mlp_init(jax.random.PRNGKey(0))
        opt = T.adam_init(params)
        bx, by = jnp.asarray(xs), jnp.asarray(ys)

        def loss_fn(p):
            return T.cross_entropy(M.mlp_apply(p, bx), by)

        l0 = float(loss_fn(params))
        for _ in range(20):
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt = T.adam_step(params, grads, opt, lr=3e-3)
        assert float(loss_fn(params)) < l0 * 0.8

    def test_cross_entropy_matches_manual(self):
        logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0]])
        labels = jnp.asarray([0, 1])
        got = float(T.cross_entropy(logits, labels))
        want = float(-np.log(np.exp(2) / (np.exp(2) + 1)))
        assert abs(got - want) < 1e-6
