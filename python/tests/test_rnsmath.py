"""Unit + property tests for the RNS math substrate (python side)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.rnsmath import (
    PAPER_TABLE1,
    RnsContext,
    egcd,
    extend_moduli,
    gcd,
    mod_inverse,
    pairwise_coprime,
    required_output_bits,
    select_moduli,
)


class TestBasics:
    def test_gcd(self):
        assert gcd(12, 18) == 6
        assert gcd(17, 13) == 1
        assert gcd(0, 5) == 5

    @given(st.integers(1, 10**6), st.integers(1, 10**6))
    def test_egcd_identity(self, a, b):
        g, x, y = egcd(a, b)
        assert a * x + b * y == g
        assert g == math.gcd(a, b)

    @given(st.integers(2, 10**4))
    def test_mod_inverse(self, m):
        for a in range(2, min(m, 20)):
            if math.gcd(a, m) == 1:
                assert (a * mod_inverse(a, m)) % m == 1

    def test_mod_inverse_rejects_noncoprime(self):
        with pytest.raises(ValueError):
            mod_inverse(6, 9)


class TestModuliSelection:
    @pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
    def test_matches_paper_table1(self, bits):
        assert select_moduli(bits, 128) == PAPER_TABLE1[bits]

    @pytest.mark.parametrize("bits,h", [(4, 16), (5, 64), (6, 256), (8, 64)])
    def test_range_covers_bout(self, bits, h):
        mods = select_moduli(bits, h)
        assert pairwise_coprime(mods)
        assert all(m < (1 << bits) for m in mods)
        assert math.prod(mods) >= (1 << required_output_bits(bits, bits, h))

    def test_minimality(self):
        # One fewer modulus cannot cover the range for the b=6, h=128 set.
        mods = select_moduli(6, 128)
        best_small = math.prod(sorted(mods, reverse=True)[: len(mods) - 1])
        assert best_small < (1 << required_output_bits(6, 6, 128))

    def test_extend_moduli_coprime(self):
        base = PAPER_TABLE1[8]
        ext = extend_moduli(base, 3)
        assert ext[: len(base)] == base
        assert len(ext) == len(base) + 3
        assert pairwise_coprime(ext)


class TestCrt:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    @given(data=st.data())
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_unsigned(self, bits, data):
        ctx = RnsContext(PAPER_TABLE1[bits])
        a = data.draw(st.integers(0, ctx.big_m - 1))
        assert ctx.crt(ctx.forward(a)) == a

    @given(data=st.data())
    @settings(max_examples=100, deadline=None)
    def test_roundtrip_signed(self, data):
        ctx = RnsContext(PAPER_TABLE1[6])
        # representable signed range is (-M/2, M/2]: for even M the values
        # -M/2 and +M/2 share residues, so -M/2 is excluded.
        half = ctx.big_m // 2
        a = data.draw(st.integers(-(half - 1), half))
        assert ctx.crt_signed(ctx.forward(a)) == a

    @given(data=st.data())
    @settings(max_examples=50, deadline=None)
    def test_homomorphism(self, data):
        """RNS is closed under + and *: residue-wise ops match integer ops."""
        ctx = RnsContext(PAPER_TABLE1[6])
        bound = int(math.isqrt(ctx.big_m)) - 1
        a = data.draw(st.integers(0, bound))
        b = data.draw(st.integers(0, bound))
        ra, rb = ctx.forward(a), ctx.forward(b)
        mul = [(x * y) % m for x, y, m in zip(ra, rb, ctx.moduli)]
        add = [(x + y) % m for x, y, m in zip(ra, rb, ctx.moduli)]
        assert ctx.crt(mul) == a * b
        assert ctx.crt(add) == a + b

    def test_array_matches_scalar(self):
        ctx = RnsContext(PAPER_TABLE1[5])
        rng = np.random.default_rng(3)
        vals = rng.integers(-(ctx.big_m // 2), ctx.big_m // 2, size=100)
        res = ctx.forward_array(vals).T  # (n, 100)
        rec = ctx.crt_signed_array(res)
        assert np.array_equal(rec, vals)
        for v in vals[:10]:
            assert ctx.crt_signed(ctx.forward(int(v))) == v

    def test_crt_coeff_property(self):
        ctx = RnsContext(PAPER_TABLE1[7])
        for c, m in zip(ctx.crt_coeff, ctx.moduli):
            # |M_i T_i|_{m_i} == 1 and == 0 mod every other modulus
            assert c % m == 1
            for other in ctx.moduli:
                if other != m:
                    assert c % other == 0

    def test_non_coprime_rejected(self):
        with pytest.raises(ValueError):
            RnsContext([6, 9, 5])


class TestEq4:
    def test_bout_formula(self):
        # b_out = b_in + b_w + log2(h) - 1 (paper Eq. 4)
        assert required_output_bits(4, 4, 128) == 14
        assert required_output_bits(6, 6, 128) == 18
        assert required_output_bits(8, 8, 128) == 22
