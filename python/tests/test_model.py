"""L2 tests: the RNS GEMM pipeline and the model zoo forward passes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.rnsmath import PAPER_TABLE1, RnsContext


class TestRnsGemmPipeline:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_tracks_fp32_matmul(self, bits):
        rng = np.random.default_rng(bits)
        x = rng.normal(0, 1, (8, 128)).astype(np.float32)
        w = rng.normal(0, 0.2, (128, 64)).astype(np.float32)
        cfg = M.RnsGemmConfig.for_bits(bits, 128)
        got = np.asarray(M.rns_gemm(jnp.asarray(x), jnp.asarray(w), cfg))
        want = x @ w
        # quantization is the ONLY error source (no ADC truncation);
        # error scale ~ h * s_in*s_w/qmax — tolerance scales with bits.
        qm = float((1 << (bits - 1)) - 1)
        scale = np.abs(x).max() * np.abs(w).max(0) * 128
        tol = (scale * (1.5 / qm)).max()
        assert np.abs(got - want).max() < tol

    def test_rns_beats_fixed_point(self):
        """Fig. 3's claim at GEMM level: RNS error << fixed-point error."""
        rng = np.random.default_rng(7)
        x = rng.normal(0, 1, (8, 128)).astype(np.float32)
        w = rng.normal(0, 0.2, (128, 64)).astype(np.float32)
        want = x @ w
        for bits in (4, 6, 8):
            cfg = M.RnsGemmConfig.for_bits(bits, 128)
            rns_err = np.abs(np.asarray(M.rns_gemm(jnp.asarray(x), jnp.asarray(w), cfg)) - want).mean()
            fp_err = np.abs(
                np.asarray(M.fixed_point_gemm(jnp.asarray(x), jnp.asarray(w), bits, 128)) - want
            ).mean()
            assert fp_err > 2.0 * rns_err, f"bits={bits}: fp {fp_err} vs rns {rns_err}"

    def test_crt_f64_matches_integer_crt(self):
        ctx = RnsContext(PAPER_TABLE1[6])
        rng = np.random.default_rng(1)
        vals = rng.integers(-(ctx.big_m // 2), ctx.big_m // 2, size=256)
        res = ctx.forward_array(vals).T.astype(np.float64)  # (n, 256)
        got = np.asarray(M.crt_f64(jnp.asarray(res), ctx)).astype(np.int64)
        assert np.array_equal(got, vals)

    def test_identity_weight(self):
        cfg = M.RnsGemmConfig.for_bits(8, 64)
        x = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32).reshape(1, 64))
        w = jnp.eye(64, dtype=jnp.float32)
        got = np.asarray(M.rns_gemm(x, w, cfg))
        np.testing.assert_allclose(got[0], np.asarray(x)[0], atol=2e-2)


class TestModels:
    @pytest.mark.parametrize(
        "name,shape",
        [("mlp", (2, 28, 28, 1)), ("cnn", (2, 28, 28, 1)), ("resnet", (2, 16, 16, 3))],
    )
    def test_forward_shapes(self, name, shape):
        init, apply = M.MODELS[name]
        params = init(jax.random.PRNGKey(0))
        x = jnp.zeros(shape, jnp.float32)
        out = apply(params, x)
        n_classes = 10
        assert out.shape == (shape[0], n_classes)
        assert np.isfinite(np.asarray(out)).all()

    def test_bert_forward(self):
        init, apply = M.MODELS["bert"]
        params = init(jax.random.PRNGKey(0))
        toks = jnp.zeros((3, M.BERT_SEQ), jnp.int64)
        out = apply(params, toks)
        assert out.shape == (3, M.BERT_CLASSES)
        assert np.isfinite(np.asarray(out)).all()

    def test_models_differentiable(self):
        init, apply = M.MODELS["mlp"]
        params = init(jax.random.PRNGKey(1))
        x = jnp.ones((4, 28, 28, 1), jnp.float32)
        y = jnp.asarray([0, 1, 2, 3])

        def loss(p):
            logits = apply(p, x)
            return -jax.nn.log_softmax(logits)[jnp.arange(4), y].mean()

        g = jax.grad(loss)(params)
        leaf = g["fc0"]["w"]
        assert float(jnp.abs(leaf).sum()) > 0.0

    def test_resnet_residual_path(self):
        """Zeroing the residual branches must reduce to stem+head behaviour."""
        init, apply = M.MODELS["resnet"]
        params = init(jax.random.PRNGKey(2))
        for b in range(M.RESNET_BLOCKS):
            params[f"block{b}_conv2"]["w"] = jnp.zeros_like(params[f"block{b}_conv2"]["w"])
            params[f"block{b}_conv2"]["b"] = jnp.zeros_like(params[f"block{b}_conv2"]["b"])
        x = jnp.asarray(np.random.default_rng(0).random((1, 16, 16, 3)), jnp.float32)
        out = apply(params, x)
        assert np.isfinite(np.asarray(out)).all()
