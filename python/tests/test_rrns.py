"""Python-side RRNS tests + cross-checks against the golden exporter."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.rnsmath import PAPER_TABLE1, extend_moduli
from compile.rrns import RrnsCode
from compile import export_golden


def make_code(bits=8, extra=2):
    return RrnsCode(extend_moduli(PAPER_TABLE1[bits], extra), len(PAPER_TABLE1[bits]))


class TestRrns:
    def test_parameters(self):
        code = make_code()
        assert code.n == 5
        assert code.correctable == 1
        assert code.legitimate_range <= min(
            np.prod([code.moduli[i] for i in g]) for g in code.groups
        )

    @given(data=st.data())
    @settings(max_examples=100, deadline=None)
    def test_clean_roundtrip(self, data):
        code = make_code()
        half = code.legitimate_range // 2
        v = data.draw(st.integers(-(half - 1), half))
        out = code.decode(code.encode(v))
        assert out is not None
        assert out[0] == v and out[1] == []

    @given(data=st.data())
    @settings(max_examples=100, deadline=None)
    def test_single_error_corrected(self, data):
        code = make_code()
        half = code.legitimate_range // 2
        v = data.draw(st.integers(-(half - 1), half))
        res = code.encode(v)
        i = data.draw(st.integers(0, code.n - 1))
        delta = data.draw(st.integers(1, code.moduli[i] - 1))
        res[i] = (res[i] + delta) % code.moduli[i]
        out = code.decode(res)
        assert out is not None, "single error must be correctable"
        assert out[0] == v
        assert out[1] == [i]

    def test_two_errors_mostly_detected(self):
        code = make_code()
        rng = np.random.default_rng(0)
        half = code.legitimate_range // 2
        detected = 0
        for _ in range(200):
            v = int(rng.integers(-(half - 1), half))
            res = code.encode(v)
            for i in rng.choice(code.n, size=2, replace=False):
                m = code.moduli[i]
                res[i] = int((res[i] + 1 + rng.integers(0, m - 1)) % m)
            if code.decode(res) is None:
                detected += 1
        assert detected > 160

    def test_best_effort_prefers_consistency(self):
        code = make_code()
        v = 123_456
        res = code.encode(v)
        res[0] = (res[0] + 7) % code.moduli[0]
        assert code.decode_best_effort(res) == v

    def test_invalid_params(self):
        with pytest.raises(ValueError):
            RrnsCode([255, 254, 253], 0)
        with pytest.raises(ValueError):
            RrnsCode([6, 9, 5], 2)


class TestGoldenExport:
    def test_export_is_self_consistent(self, tmp_path):
        path = export_golden.export(str(tmp_path), seed=1, cases=64)
        from compile import tensorstore as TS

        t = TS.load(path)
        # forward goldens hold for every bit width
        for bits, moduli in PAPER_TABLE1.items():
            assert np.array_equal(t[f"b{bits}.moduli"], np.asarray(moduli))
            vals = t[f"b{bits}.values"]
            res = t[f"b{bits}.residues"]
            assert np.array_equal(np.mod(vals[:, None], np.asarray(moduli)), res)
            assert np.array_equal(t[f"b{bits}.crt"], vals)
        # rrns goldens decode to the recorded expectations
        code = RrnsCode(list(t["rrns.moduli"]), int(t["rrns.k"][0]))
        for word, want in zip(t["rrns.words"], t["rrns.expected"]):
            got = code.decode([int(r) for r in word])
            if want == export_golden.DETECTED_SENTINEL:
                assert got is None
            else:
                assert got is not None and got[0] == want

    def test_deterministic(self, tmp_path):
        p1 = export_golden.export(str(tmp_path / "a"), seed=5, cases=16)
        p2 = export_golden.export(str(tmp_path / "b"), seed=5, cases=16)
        assert open(p1, "rb").read() == open(p2, "rb").read()
