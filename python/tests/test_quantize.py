"""Tests for the paper §III-B quantization / scaling scheme."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize as q
from compile.rnsmath import PAPER_TABLE1, RnsContext


class TestQuantize:
    @given(st.integers(2, 8))
    def test_qmax(self, bits):
        assert q.qmax(bits) == (1 << (bits - 1)) - 1

    @given(data=st.data())
    @settings(max_examples=50, deadline=None)
    def test_activation_bounds(self, data):
        bits = data.draw(st.sampled_from([4, 6, 8]))
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 3, (4, 32)).astype(np.float32))
        xq, s = q.quantize_activations(x, bits)
        assert np.abs(np.asarray(xq)).max() <= q.qmax(bits)
        assert np.array_equal(np.asarray(xq), np.round(np.asarray(xq)))  # integers
        assert s.shape == (4, 1)

    def test_weight_scale_per_output(self):
        w = jnp.asarray(np.array([[1.0, 10.0], [2.0, -20.0], [0.5, 5.0]], np.float32))
        wq, s = q.quantize_weights(w, 8)
        assert s.shape == (1, 2)
        assert float(s[0, 0]) == 2.0 and float(s[0, 1]) == 20.0

    def test_zero_vector_scale_guard(self):
        xq, s = q.quantize_activations(jnp.zeros((2, 8)), 6)
        assert np.all(np.asarray(s) == 1.0)
        assert np.all(np.asarray(xq) == 0.0)

    def test_quantization_error_bound(self):
        """|dequant(quant(x)) - x| <= s / (2 qmax) elementwise (round-half)."""
        rng = np.random.default_rng(0)
        x = rng.normal(0, 1, (8, 64)).astype(np.float32)
        xq, s = q.quantize_activations(jnp.asarray(x), 8)
        recon = np.asarray(xq) * np.asarray(s) / q.qmax(8)
        assert np.abs(recon - x).max() <= np.asarray(s).max() / (2 * q.qmax(8)) + 1e-6


class TestResidueMapping:
    @given(data=st.data())
    @settings(max_examples=50, deadline=None)
    def test_signed_wraps_and_roundtrips(self, data):
        bits = data.draw(st.sampled_from([4, 6, 8]))
        ctx = RnsContext(PAPER_TABLE1[bits])
        qm = int(q.qmax(bits))
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        vals = rng.integers(-qm, qm + 1, (3, 7))
        res = q.to_residues(jnp.asarray(vals, jnp.float32), jnp.asarray(ctx.moduli, jnp.float32))
        r = np.asarray(res).astype(np.int64)
        mods = np.array(ctx.moduli)
        assert (r >= 0).all()
        assert (r < mods.reshape(-1, 1, 1)).all()
        rec = ctx.crt_signed_array(r.reshape(ctx.n, -1)).reshape(vals.shape)
        assert np.array_equal(rec, vals)

    def test_dequantize_inverts_scales(self):
        y = jnp.asarray(np.array([[100.0, -200.0]], np.float32))
        s_in = jnp.asarray([[2.0]])
        s_w = jnp.asarray([[3.0, 4.0]])
        out = np.asarray(q.dequantize(y, s_in, s_w, 8))
        qm = q.qmax(8)
        np.testing.assert_allclose(out, [[100 * 6 / qm**2, -200 * 8 / qm**2]], rtol=1e-6)
