"""AOT export tests: HLO text is produced, parseable-looking, and the
lowered pipelines numerically match their eager counterparts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import RnsGemmConfig, fixed_point_gemm, rns_gemm


class TestLowering:
    def test_rns_mvm_hlo_text(self):
        cfg = RnsGemmConfig.for_bits(6, aot.H)
        text = aot.to_hlo_text(aot.lower_rns_mvm(cfg))
        assert text.startswith("HloModule")
        assert "f32[4,8,128]" in text  # n=4 residue channels
        # the modular reduction lowers to floor/divide/multiply/subtract
        assert "floor" in text

    def test_rns_gemm_hlo_contains_crt_constants(self):
        cfg = RnsGemmConfig.for_bits(4, aot.H)
        text = aot.to_hlo_text(aot.lower_rns_gemm(cfg))
        assert text.startswith("HloModule")
        # CRT runs in f64 in the lowered pipeline
        assert "f64" in text

    def test_fixed_point_hlo(self):
        text = aot.to_hlo_text(aot.lower_fixed_point(8))
        assert text.startswith("HloModule")
        assert f"f32[{aot.BATCH},{aot.H}]" in text

    def test_lowered_matches_eager(self):
        """Executing the lowered computation (via jax compile) must equal the
        eager pipeline — guards against lowering-time constant drift."""
        cfg = RnsGemmConfig.for_bits(6, aot.H)
        lowered = aot.lower_rns_gemm(cfg)
        compiled = lowered.compile()
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (aot.BATCH, aot.H)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 0.2, (aot.H, aot.H)), jnp.float32)
        got = np.asarray(compiled(x, w)[0])
        want = np.asarray(rns_gemm(x, w, cfg))
        np.testing.assert_array_equal(got, want)


class TestExport:
    def test_export_writes_all_artifacts(self, tmp_path):
        out = str(tmp_path)
        aot.export(out)
        for b in aot.BITS:
            for stem in ("rns_mvm", "rns_gemm", "fixed_point"):
                p = os.path.join(out, f"{stem}_b{b}.hlo.txt")
                assert os.path.exists(p), p
                with open(p) as f:
                    assert f.read(9) == "HloModule"
        assert os.path.exists(os.path.join(out, "model.hlo.txt"))
        manifest = open(os.path.join(out, "manifest.txt")).read()
        assert "moduli_b6=63,62,61,59" in manifest
        assert f"h={aot.H}" in manifest
