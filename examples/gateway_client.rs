//! Loopback gateway driver: connect N concurrent clients to a running
//! `rns-analog serve --listen=...` gateway, pipeline requests over each
//! session, and report throughput — the CI smoke job runs exactly this
//! against a freshly started server and then asks it to drain with
//! `--shutdown`.
//!
//! Run:
//!   rns-analog serve --listen=127.0.0.1:7171 &
//!   cargo run --release --example gateway_client -- \
//!       --addr=127.0.0.1:7171 --requests=24 --clients=4 --shutdown
//!
//! With `--retries=N` each client runs through the supervision-aware
//! `RetryClient` (sequential round trips instead of pipelining):
//! connection drops and transient errors are retried with seeded
//! backoff, which is what the chaos smoke job leans on.  `--token=` sets
//! the admin token for the final stats/shutdown session, and
//! `--deadline-ms=` attaches a per-request deadline to every `Infer`.
//!
//! The default model is `synthetic-mlp` (seeded in-process weights), so
//! the pair works without `make artifacts`.

use std::time::Instant;

use rns_analog::net::{Client, RetryClient, RetryPolicy};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::cli::Args;
use rns_analog::util::rng::Rng;

fn main() {
    let mut args = Args::parse_from(std::env::args().skip(1)).expect("args");
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let requests = args.get_parsed::<usize>("requests", 24).unwrap();
    let clients = args.get_parsed::<usize>("clients", 4).unwrap().max(1);
    let model = args.get_or("model", SYNTHETIC_MLP);
    let retries = args.get_parsed::<u32>("retries", 0).unwrap();
    let deadline_ms = args.get_parsed::<u32>("deadline-ms", 0).unwrap();
    let token = args.get_or("token", "");
    let shutdown = args.flag("shutdown");
    let traces = args.flag("traces");
    if let Err(e) = args.check_unknown() {
        eprintln!("{e}");
        std::process::exit(2);
    }

    let per_client = requests.div_ceil(clients);
    let total = per_client * clients;
    println!("driving {addr}: {clients} client(s) x {per_client} request(s), model `{model}`");

    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let model = model.clone();
        threads.push(std::thread::spawn(move || -> Result<usize, String> {
            let mut rng = Rng::seed_from(42 + c as u64);
            let mut next_input = move || {
                Batch::Images(Nhwc::from_vec(
                    1,
                    28,
                    28,
                    1,
                    (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
                ))
            };
            if retries > 0 {
                // crash-tolerant path: sequential round trips with
                // reconnect + seeded-backoff retry (per-client seed so
                // simultaneous retriers spread out)
                let policy = RetryPolicy { retries, seed: 42 + c as u64, ..RetryPolicy::default() };
                let mut client = RetryClient::new(&addr, policy);
                client.set_deadline_ms(deadline_ms);
                let mut ok = 0usize;
                for _ in 0..per_client {
                    let reply = client.infer(&model, &next_input()).map_err(|e| e.to_string())?;
                    assert_eq!(reply.logits.rows, 1, "one sample in, one logit row out");
                    ok += 1;
                }
                if client.retries > 0 || client.reconnects > 0 {
                    println!(
                        "client {c}: {} retried attempt(s), {} reconnect(s)",
                        client.retries, client.reconnects
                    );
                }
                client.close();
                return Ok(ok);
            }
            let mut client = Client::connect(&addr)?;
            client.set_deadline_ms(deadline_ms);
            // pipeline: submit everything, then drain the replies
            for _ in 0..per_client {
                client.submit(&model, &next_input())?;
            }
            let mut ok = 0usize;
            for _ in 0..per_client {
                let reply = client.recv_infer()?;
                assert_eq!(reply.logits.rows, 1, "one sample in, one logit row out");
                ok += 1;
            }
            client.close();
            Ok(ok)
        }));
    }
    let mut ok = 0usize;
    let mut failures = Vec::new();
    for t in threads {
        match t.join().expect("client thread") {
            Ok(n) => ok += n,
            Err(e) => failures.push(e),
        }
    }
    let dt = t0.elapsed();
    println!(
        "completed {ok}/{total} request(s) in {:.2}s ({:.1} req/s)",
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64().max(1e-9)
    );
    for e in &failures {
        eprintln!("client error: {e}");
    }

    // one admin session: liveness, a stats peek, optional drain request
    let mut admin = Client::connect(&addr).expect("admin connect");
    admin.set_admin_token(&token);
    admin.ping().expect("ping");
    let stats = admin.stats().expect("stats");
    for prefix in ["gateway:", "supervision:"] {
        if let Some(line) = stats.lines().find(|l| l.starts_with(prefix)) {
            println!("server: {}", line.trim());
        }
    }
    if traces {
        let report = admin.traces().expect("traces");
        for line in report.lines() {
            println!("server: {}", line.trim());
        }
    }
    if shutdown {
        let info = admin.shutdown_server().expect("shutdown request");
        println!("shutdown requested ({info})");
    }
    admin.close();

    if !failures.is_empty() || ok != total {
        std::process::exit(1);
    }
}
