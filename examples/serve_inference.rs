//! End-to-end serving driver (the repo's E2E validation — EXPERIMENTS.md §E2E).
//!
//! Loads the trained model zoo, starts the L3 coordinator with RNS-analog
//! workers whose modular MVMs execute through the AOT-compiled pallas
//! kernel via PJRT, streams the frozen evaluation sets through as batched
//! requests, and reports accuracy + latency/throughput.  This proves all
//! three layers compose: rust coordinator -> PJRT runtime -> pallas HLO.
//!
//! Run: cargo run --release --example serve_inference [-- --requests=96 --backend=rns --workers=4]
//!   --backend=rns-pjrt uses the PJRT engine on the hot path (slower but
//!   exercises the full AOT stack; default for the first 16 requests).
//!   With --backend=rns the workers share one execution fabric (one
//!   process-wide pool of fan-out threads, bounded by cores − 1 whatever
//!   --workers says) — its utilization appears in the shutdown report's
//!   `fabric:` line.

use std::collections::HashMap;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use rns_analog::nn::dataset::{dataset_for_model, load_eval_set};
use rns_analog::nn::models::Batch;
use rns_analog::runtime::default_artifacts_dir;
use rns_analog::tensor::Nhwc;
use rns_analog::util::cli::Args;

fn main() {
    let mut args = Args::parse_from(std::env::args().skip(1)).expect("args");
    let artifacts = args.get_or("artifacts-dir", &default_artifacts_dir());
    let requests_per_model = args.get_parsed::<usize>("requests", 48).unwrap();
    let bits = args.get_parsed::<u32>("bits", 6).unwrap();
    let workers = args.get_parsed::<usize>("workers", 2).unwrap();
    let backend = match args.get_or("backend", "rns-pjrt").as_str() {
        "rns" => BackendKind::Rns { bits, redundant: 0, attempts: 1, noise: NoiseModel::None },
        "rns-pjrt" => {
            BackendKind::RnsPjrt { bits, redundant: 0, attempts: 1, noise: NoiseModel::None }
        }
        "fixed" => BackendKind::FixedPoint { bits },
        _ => BackendKind::Fp32,
    };
    println!("serving with backend {backend:?}, {requests_per_model} requests/model\n");

    let mut cfg = CoordinatorConfig::new(backend, &artifacts);
    cfg.workers = workers;
    cfg.batcher = BatcherConfig { max_batch: 8, ..Default::default() };
    let coord = Coordinator::start(cfg);
    if let Some(fabric) = coord.fabric() {
        let s = fabric.stats();
        println!(
            "execution fabric: {} helper thread(s) shared by {workers} worker(s), \
             budget {} helper(s)/job\n",
            s.helper_threads, s.budget
        );
    }

    // stream single-sample requests for two models, interleaved, and track
    // the ground-truth label of every request id
    let mut truth: HashMap<u64, i64> = HashMap::new();
    let mut expected = 0usize;
    for model in ["mlp", "bert"] {
        let eval = load_eval_set(&artifacts, dataset_for_model(model)).expect("eval set");
        for i in 0..requests_per_model.min(eval.len()) {
            let input = match &eval.input {
                Batch::Images(t) => {
                    let stride = t.h * t.w * t.c;
                    Batch::Images(Nhwc::from_vec(
                        1,
                        t.h,
                        t.w,
                        t.c,
                        t.data[i * stride..(i + 1) * stride].to_vec(),
                    ))
                }
                Batch::Tokens { tokens, seq, .. } => Batch::Tokens {
                    tokens: tokens[i * seq..(i + 1) * seq].to_vec(),
                    batch: 1,
                    seq: *seq,
                },
            };
            let id = coord.submit(model, input);
            truth.insert(id, eval.labels[i]);
            expected += 1;
        }
    }

    // collect + score
    let mut correct = 0usize;
    let mut failures = 0usize;
    for _ in 0..expected {
        let resp = coord.recv().expect("response");
        match &resp.result {
            Ok(logits) => {
                let pred = logits
                    .row(0)
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i64)
                    .unwrap();
                if pred == truth[&resp.id] {
                    correct += 1;
                }
            }
            Err(e) => {
                failures += 1;
                eprintln!("request {} failed: {e}", resp.id);
            }
        }
    }
    println!("accuracy over served requests: {}/{} = {:.1}%", correct, expected,
             100.0 * correct as f64 / expected as f64);
    assert_eq!(failures, 0, "no request may fail");
    println!("\n--- coordinator report ---\n{}", coord.shutdown());
}
