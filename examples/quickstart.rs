//! Quickstart: the paper's core claim in 60 lines.
//!
//! Builds a 6-bit RNS analog core and a 6-bit fixed-point analog core,
//! pushes the same GEMM through both, and shows that the RNS core's error
//! is quantization-only while the fixed-point core loses b_out - b_ADC
//! bits per dot product (paper Fig. 3) — then verifies the AOT pallas
//! kernel through PJRT agrees with the native engine bit-for-bit.
//!
//! Run: cargo run --release --example quickstart

use rns_analog::analog::{FixedPointCore, NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::nn::dataset::random_gemm_pair;
use rns_analog::runtime::{default_artifacts_dir, ModularGemmEngine, NativeEngine, PjrtEngine, PjrtRuntime};
use rns_analog::tensor::gemm::gemm_f32;
use rns_analog::tensor::MatI;
use rns_analog::util::rng::Rng;

fn main() {
    let bits = 6;
    let h = 128;
    let mut rng = Rng::seed_from(1);
    let (x, w) = random_gemm_pair(&mut rng, 8, h, 32, 1.0);

    // FP32 ground truth
    let want = gemm_f32(&x, &w);

    // the two competing analog cores (Table I configuration, b = 6)
    let mut rns = RnsCore::new(RnsCoreConfig::for_bits(bits, h)).expect("rns core");
    let mut fxp = FixedPointCore::new(bits, h, NoiseModel::None, 0);

    let got_rns = rns.gemm_quantized(&x, &w);
    let got_fxp = fxp.gemm_quantized(&x, &w);

    let mean_err = |m: &rns_analog::tensor::MatF| {
        m.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
            / want.data.len() as f64
    };
    println!("GEMM (8x{h}) @ ({h}x32), b = {bits}:");
    println!("  RNS core    mean |err| = {:.5}  (moduli {:?})", mean_err(&got_rns), rns.cfg.moduli);
    println!(
        "  fixed-point mean |err| = {:.5}  ({}x larger)",
        mean_err(&got_fxp),
        (mean_err(&got_fxp) / mean_err(&got_rns)).round()
    );
    println!(
        "  energy: rns adc={}  fxp adc={}",
        rns_analog::util::format_si(rns.meter.adc_joules, "J"),
        rns_analog::util::format_si(fxp.meter.adc_joules, "J"),
    );

    // AOT path: the pallas kernel compiled at build time, loaded via PJRT
    let artifacts = default_artifacts_dir();
    match PjrtRuntime::cpu()
        .map_err(|e| format!("{e:#}"))
        .and_then(|rt| PjrtEngine::load(&rt, &artifacts, bits).map_err(|e| format!("{e:#}")))
    {
        Ok(mut engine) => {
            let moduli = engine.moduli.clone();
            let xr: Vec<MatI> = moduli
                .iter()
                .map(|&m| MatI::from_vec(4, h, (0..4 * h).map(|_| rng.gen_range(m) as i64).collect()))
                .collect();
            let wr: Vec<MatI> = moduli
                .iter()
                .map(|&m| {
                    MatI::from_vec(h, 16, (0..h * 16).map(|_| rng.gen_range(m) as i64).collect())
                })
                .collect();
            let pjrt_out = engine.matmul_mod(&xr, &wr, &moduli);
            let native_out = NativeEngine.matmul_mod(&xr, &wr, &moduli);
            assert_eq!(
                pjrt_out.iter().map(|m| &m.data).collect::<Vec<_>>(),
                native_out.iter().map(|m| &m.data).collect::<Vec<_>>()
            );
            println!("  AOT pallas kernel via PJRT == native engine: bit-identical ✓");
        }
        Err(e) => println!("  (PJRT artifacts unavailable: {e} — run `make artifacts`)"),
    }
}
