//! Drift campaign (paper §IV, ROADMAP PR-3 open item): drive
//! `FaultSpec::TemporalBurst` — a corrupted elems × width rectangle that
//! persists across consecutive tiles, modeling drift — through a *full
//! model forward* and tabulate p_err against burst geometry through the
//! RRNS detect → recompute retry loop.
//!
//! The model is a synthetic-weight MLP (784-256-128-10 via
//! `Mlp::synthetic`), so no `make artifacts` step is needed; every row
//! replays bit-for-bit from the campaign seed (see
//! `tests/integration_drift.rs` for the determinism assertion).
//!
//! Two injection sites (`RnsCoreConfig::with_fault_site`):
//!   * `capture` — drift hits the ADC capture; the retry recomputes the
//!     dot product clean, so attempts > 1 recovers width > t bursts;
//!   * `array` — drift hits the channel outputs themselves; retries
//!     re-read the same corruption until the event's tile budget
//!     expires, so width > t exhausts `max_attempts` no matter how
//!     large the budget — the serving analogue of a stuck array fault.
//!
//! p_err here is the fraction of decoded output elements that stayed
//! wrong after the retry budget (`exhausted / decoded`).
//!
//! Run: cargo run --release --example drift_campaign [-- --seed=11 --batch=8]

use rns_analog::analog::{InjectionSite, RnsCore, RnsCoreConfig};
use rns_analog::nn::models::{Batch, Mlp, Model};
use rns_analog::rns::inject::FaultSpec;
use rns_analog::tensor::Nhwc;
use rns_analog::util::cli::Args;
use rns_analog::util::rng::Rng;

fn main() {
    let mut args = Args::parse_from(std::env::args().skip(1)).expect("args");
    let seed = args.get_parsed::<u64>("seed", 11).unwrap();
    let batch = args.get_parsed::<usize>("batch", 8).unwrap();
    let bits = 8u32;
    let redundant = 2usize; // RRNS(6,4) over the Table-I b=8 moduli: t = 1

    let model = Mlp::synthetic(1);
    let mut rng = Rng::seed_from(seed ^ 0xD51F7);
    let input = Batch::Images(Nhwc::from_vec(
        batch,
        28,
        28,
        1,
        (0..batch * 28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ));

    // clean reference forward (same quantization, no faults)
    let mut clean_core = RnsCore::new(RnsCoreConfig::for_bits(bits, 128).with_rrns(redundant, 1))
        .expect("clean core");
    let clean = model.forward(&input, &mut clean_core);

    println!(
        "TemporalBurst drift campaign: synthetic MLP forward, RRNS({}, {}), seed {seed}",
        clean_core.n_channels(),
        clean_core.n_channels() - redundant,
    );
    println!(
        "burst rectangle: elems x width persisting across `tiles` consecutive tiles; \
         p_err = exhausted / decoded\n"
    );
    println!(
        "{:>7} {:>5} {:>6} {:>9} {:>9} {:>10} {:>10} {:>10} {:>10} {:>11}",
        "site",
        "width",
        "tiles",
        "attempts",
        "decoded",
        "corrected",
        "detected",
        "exhausted",
        "p_err",
        "logit-mism"
    );

    for &(site, site_name) in
        &[(InjectionSite::Capture, "capture"), (InjectionSite::Array, "array")]
    {
        for &width in &[1usize, 2, 3] {
            for &tiles in &[1usize, 2, 4, 8] {
                for &attempts in &[1u32, 3] {
                    let spec = FaultSpec::TemporalBurst { tiles, elems: 8, width };
                    let mut core = RnsCore::new(
                        RnsCoreConfig::for_bits(bits, 128)
                            .with_rrns(redundant, attempts)
                            .with_fault_injection(spec, seed)
                            .with_fault_site(site),
                    )
                    .expect("drift core");
                    let logits = model.forward(&input, &mut core);
                    let s = core.stats;
                    let p_err = s.exhausted as f64 / s.decoded.max(1) as f64;
                    let mismatch = logits
                        .data
                        .iter()
                        .zip(&clean.data)
                        .filter(|(a, b)| a.to_bits() != b.to_bits())
                        .count();
                    println!(
                        "{site_name:>7} {width:>5} {tiles:>6} {attempts:>9} {:>9} {:>10} {:>10} \
                         {:>10} {:>10.4} {:>6}/{:<4}",
                        s.decoded,
                        s.corrected,
                        s.detections,
                        s.exhausted,
                        p_err,
                        mismatch,
                        logits.data.len(),
                    );
                }
            }
        }
    }

    println!(
        "\nreading the table: width <= t(=1) is corrected exactly at either site (p_err 0, \
         no logit mismatch).  width > t splits the sites apart: capture-side drift is \
         detected and recovered by attempts > 1 (the recompute re-reads clean arrays), \
         while array-side drift survives every recompute — p_err stays put however large \
         the attempt budget — because the corruption lives in the dot product itself \
         until the event's tile budget expires.  Longer persistence (tiles) scales how \
         many tiles share one rectangle, not the per-tile damage."
    );
}
