//! Fault-tolerance walkthrough (paper §IV): inject residue noise into the
//! RNS core and watch the RRNS(n, k) code detect, correct, and — via the
//! coordinator's recompute loop — absorb analog errors that would
//! otherwise destroy the result.
//!
//! Run: cargo run --release --example fault_tolerance [-- --p=0.02]

use rns_analog::analog::{NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::nn::dataset::random_gemm_pair;
use rns_analog::rns::rrns::{Decode, RrnsCode};
use rns_analog::rns::{extend_moduli, paper_table1};
use rns_analog::tensor::gemm::gemm_f32;
use rns_analog::util::cli::Args;
use rns_analog::util::rng::Rng;

fn main() {
    let mut args = Args::parse_from(std::env::args().skip(1)).expect("args");
    let p = args.get_parsed::<f64>("p", 0.02).unwrap();
    let bits = 8u32;

    // 1. codeword-level demo: encode, corrupt, decode
    let base = paper_table1(bits).unwrap();
    let moduli = extend_moduli(base, 2).unwrap();
    let code = RrnsCode::new(&moduli, base.len()).unwrap();
    println!("RRNS(n={}, k={}) over moduli {:?}", code.n(), code.k, moduli);
    println!("  corrects up to {} residue error(s), legitimate range 2^{:.1}\n",
             code.correctable(), (code.legitimate_range as f64).log2());

    let value = -123_456i64;
    let mut residues = code.encode(value);
    println!("encode({value}) = {residues:?}");
    residues[1] = (residues[1] + 17) % moduli[1]; // corrupt one residue
    println!("corrupted      = {residues:?}");
    match code.decode(&residues) {
        Decode::Ok { value: v, suspects } => {
            println!("decode -> {v} (suspect residues {suspects:?}) — corrected ✓\n")
        }
        Decode::Detected => println!("decode -> detected-but-uncorrectable\n"),
    }

    // 2. end-to-end: the same GEMM through three cores under noise p
    let mut rng = Rng::seed_from(3);
    let (x, w) = random_gemm_pair(&mut rng, 8, 128, 16, 1.0);
    let want = gemm_f32(&x, &w);
    let mean_err = |m: &rns_analog::tensor::MatF| {
        m.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
            / want.data.len() as f64
    };
    let noise = NoiseModel::ResidueFlip { p };

    let mut unprotected =
        RnsCore::new(RnsCoreConfig::for_bits(bits, 128).with_noise(noise).with_seed(1)).unwrap();
    let mut protected1 = RnsCore::new(
        RnsCoreConfig::for_bits(bits, 128).with_noise(noise).with_rrns(2, 1).with_seed(1),
    )
    .unwrap();
    let mut protected3 = RnsCore::new(
        RnsCoreConfig::for_bits(bits, 128).with_noise(noise).with_rrns(2, 3).with_seed(1),
    )
    .unwrap();

    println!("GEMM under residue noise p = {p}:");
    println!("  plain RNS (no redundancy)     mean |err| = {:.4}", mean_err(&unprotected.gemm_quantized(&x, &w)));
    let e1 = mean_err(&protected1.gemm_quantized(&x, &w));
    println!(
        "  RRNS n-k=2, attempts=1        mean |err| = {:.4}  (corrected {}, detections {}, exhausted {})",
        e1, protected1.stats.corrected, protected1.stats.detections, protected1.stats.exhausted
    );
    let e3 = mean_err(&protected3.gemm_quantized(&x, &w));
    println!(
        "  RRNS n-k=2, attempts=3        mean |err| = {:.4}  (corrected {}, detections {}, exhausted {})",
        e3, protected3.stats.corrected, protected3.stats.detections, protected3.stats.exhausted
    );
    println!(
        "\ntwo-tier decode split: {} of {} elements took the batched no-fault \
         fast path, {} fell back to voting",
        protected3.stats.fast_path_elems, protected3.stats.decoded, protected3.stats.voted_elems
    );
    println!("energy overhead of redundancy: {} vs {} adc conversions",
             protected3.meter.adc_conversions, unprotected.meter.adc_conversions);
}
