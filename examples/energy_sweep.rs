//! Energy design-space sweep (paper §V / Fig. 7, extended): for each
//! precision b and array height h, compare the data-converter energy per
//! output element of the RNS core against a same-precision fixed-point
//! core, and show the measured energy of an actual model inference.
//!
//! Run: cargo run --release --example energy_sweep

use rns_analog::analog::energy::{adc_energy, dac_energy};
use rns_analog::analog::{Fp32Backend, RnsCore, RnsCoreConfig};
use rns_analog::exp::report::Report;
use rns_analog::nn::dataset::{dataset_for_model, load_eval_set};
use rns_analog::nn::models::{accuracy, load_model};
use rns_analog::rns::moduli::{required_output_bits, select_moduli};
use rns_analog::runtime::default_artifacts_dir;
use rns_analog::util::format_si;

fn main() {
    // 1. the design-space table (analytic, Eqs. 6-7)
    let mut rep = Report::new("Energy per output element across the design space");
    rep.header(&["h", "b", "n moduli", "RNS E_ADC", "FXP E_ADC (b_out)", "ratio"]);
    for &h in &[64usize, 128, 256] {
        for &bits in &[4u32, 6, 8] {
            let n = select_moduli(bits, h).unwrap().len();
            let b_out = required_output_bits(bits, bits, h);
            let rns = n as f64 * adc_energy(bits);
            let fxp = adc_energy(b_out);
            rep.row(vec![
                h.to_string(),
                bits.to_string(),
                n.to_string(),
                format_si(rns, "J"),
                format_si(fxp, "J"),
                format!("{:.2e}x", fxp / rns),
            ]);
        }
    }
    println!("{}\n", rep.render());

    // 2. measured: a real model inference through the RNS core with the
    //    energy meter running
    let artifacts = default_artifacts_dir();
    match (load_model(&artifacts, "cnn"), load_eval_set(&artifacts, dataset_for_model("cnn"))) {
        (Ok(model), Ok(eval)) => {
            let eval = eval.take(32);
            let fp32_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
            let mut rep = Report::new("Measured data-converter energy: cnn inference, 32 images");
            rep.header(&["b", "accuracy (vs fp32)", "DAC conv", "ADC conv", "E_DAC", "E_ADC", "E_ADC/sample"]);
            for bits in [4u32, 6, 8] {
                let mut core = RnsCore::new(RnsCoreConfig::for_bits(bits, 128)).unwrap();
                let acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut core);
                let m = core.meter;
                rep.row(vec![
                    bits.to_string(),
                    format!("{:.1}% ({:.1}%)", 100.0 * acc, 100.0 * acc / fp32_acc),
                    m.dac_conversions.to_string(),
                    m.adc_conversions.to_string(),
                    format_si(m.dac_joules, "J"),
                    format_si(m.adc_joules, "J"),
                    format_si(m.adc_joules / 32.0, "J"),
                ]);
            }
            println!("{}", rep.render());
            println!(
                "\n(equivalent fixed-point core at the same output precision would spend\n {} per ADC conversion at b_out = 18 vs {} at b = 6 — the paper's point)",
                format_si(adc_energy(18), "J"),
                format_si(adc_energy(6), "J")
            );
        }
        _ => println!("(artifacts not built — run `make artifacts` for the measured half)"),
    }
}
